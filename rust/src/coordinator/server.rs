//! Batched LM serving loop: the L3 request path over the quantized model.
//!
//! A worker thread owns the model backend (native forward, streamed
//! compressed-weights forward, cache-aware forward, or PJRT logits
//! artifact), drains the request queue into bounded batches, and steps all
//! requests of a batch in **lockstep**: every active generate/score
//! sequence contributes one prefix to a single
//! [`LmBackend::logits_last_batch`] call per step, so a batched backend
//! runs one forward (and, for [`StreamingNativeBackend`], one streaming
//! decode of each weight panel) for the whole batch.
//! [`CachedNativeBackend`] additionally turns those lockstep calls into
//! *prefill once, then one-token steps* against a paged
//! [`crate::kvcache::PagedKvCache`], dropping per-token cost from O(T²)
//! to O(T). [`super::metrics::ServerMetrics`] tracks latency/throughput
//! plus, per backend kind, cumulative weight-decode traffic and KV-cache
//! occupancy/quantization counters.
//!
//! [`start_continuous`] runs the same request channel through the
//! continuous-batching scheduler instead
//! ([`crate::serving::ContinuousScheduler`]): sequences join and leave
//! the step batch per token, long prompts prefill in chunks, and the
//! scheduler preempts/resumes sequences against KV-page pressure.
//! [`CachedNativeBackend`] exposes the per-sequence
//! step/retire/preempt/resume hooks that mode schedules through (its
//! [`SeqBackend`] impl).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use crate::eval::native_fwd::{self, DenseLinear, LinearOp, StreamedLinear};
use crate::kvcache::{KvCacheOpts, KvCacheStats, PagedKvCache, SeqId, SpilledSeq};
use crate::linalg::Mat;
use crate::model::ModelConfig;
use crate::obs::{Mark, RequestTimeline};
use crate::quant::format::QuantizedModel;
use crate::runtime::exec::LogitsExec;
use crate::runtime::Engine;
use crate::serving::{ContinuousOpts, ContinuousScheduler, SeqBackend};
use crate::shard::{ShardOpts, ShardStat, ShardedLinear, ShardedMatmul};
use crate::tensor::TensorStore;

use super::metrics::ServerMetrics;

/// Model backend abstraction: last-position logits for a token prefix.
/// Backends are created *inside* the server thread (PJRT handles are not
/// Send), so [`start`] takes a factory closure.
pub trait LmBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Last-position logits for several prefixes at once. The default
    /// loops [`LmBackend::logits_last`]; batched backends override this to
    /// run one forward for the whole batch.
    fn logits_last_batch(&mut self, prefixes: &[&[i32]]) -> Result<Vec<Vec<f32>>> {
        prefixes.iter().map(|t| self.logits_last(t)).collect()
    }

    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Cumulative streaming-decode statistics, if this backend executes
    /// from compressed weights (None for dense/PJRT backends).
    fn decode_stats(&self) -> Option<DecodeStats> {
        None
    }

    /// Called by the lockstep loop when a drained batch fully completes;
    /// cache-aware backends release per-sequence state here. No-op by
    /// default.
    fn end_batch(&mut self) {}

    /// KV-cache counters, if this backend maintains a paged KV cache
    /// (None for cacheless backends).
    fn cache_stats(&self) -> Option<KvCacheStats> {
        None
    }

    /// Per-shard decode counters, if this backend executes tensor-parallel
    /// over a [`ShardedMatmul`] (None otherwise).
    fn shard_stats(&self) -> Option<Vec<ShardStat>> {
        None
    }

    /// Draft/verify counters, if this backend decodes speculatively
    /// (None for plain backends).
    fn spec_stats(&self) -> Option<crate::spec::SpecStats> {
        None
    }
}

/// Pad each prefix to `seq_len` (keeping its tail) and return the flat
/// (B·T) token buffer plus the last valid position of each row.
pub(crate) fn pad_prefixes(seq_len: usize, prefixes: &[&[i32]]) -> (Vec<i32>, Vec<usize>) {
    let mut flat = Vec::with_capacity(seq_len * prefixes.len());
    let mut last = Vec::with_capacity(prefixes.len());
    for tokens in prefixes {
        let keep = tokens.len().min(seq_len);
        let mut row = tokens[tokens.len() - keep..].to_vec();
        last.push(keep.max(1) - 1);
        row.resize(seq_len, 0);
        flat.extend_from_slice(&row);
    }
    (flat, last)
}

/// Pull each row's last-position logits out of a flat (B·T × V) matrix —
/// the gather shared by every native backend.
pub(crate) fn gather_last_rows(
    logits: &crate::linalg::Mat,
    seq_len: usize,
    last: &[usize],
) -> Vec<Vec<f32>> {
    last.iter()
        .enumerate()
        .map(|(b, &l)| logits.row(b * seq_len + l).to_vec())
        .collect()
}

/// Native-forward backend over dense weights (no artifacts needed).
pub struct NativeBackend {
    pub cfg: ModelConfig,
    pub store: TensorStore,
}

impl LmBackend for NativeBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        Ok(self.logits_last_batch(&[tokens])?.remove(0))
    }

    fn logits_last_batch(&mut self, prefixes: &[&[i32]]) -> Result<Vec<Vec<f32>>> {
        let t = self.cfg.seq_len;
        let (flat, last) = pad_prefixes(t, prefixes);
        let logits = native_fwd::forward(&self.cfg, &self.store, &flat, prefixes.len(), None)?;
        Ok(gather_last_rows(&logits, t, &last))
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}

/// Native-forward backend that executes every quantized linear **directly
/// from the compressed container** through the batched streaming engine —
/// no layer is ever fully dequantized (peak decoded working set is one
/// panel, tracked in [`DecodeStats::peak_decoded`]). Non-quantized
/// parameters (embeddings, norm gains) come from `store`.
pub struct StreamingNativeBackend {
    pub cfg: ModelConfig,
    pub store: TensorStore,
    pub qm: QuantizedModel,
    pub engine: StreamingMatmul,
    pub stats: DecodeStats,
}

impl LmBackend for StreamingNativeBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        Ok(self.logits_last_batch(&[tokens])?.remove(0))
    }

    fn logits_last_batch(&mut self, prefixes: &[&[i32]]) -> Result<Vec<Vec<f32>>> {
        let t = self.cfg.seq_len;
        let (flat, last) = pad_prefixes(t, prefixes);
        let mut lin = StreamedLinear {
            qm: &self.qm,
            store: &self.store,
            engine: &self.engine,
            stats: DecodeStats::default(),
        };
        let logits = native_fwd::forward_with(
            &self.cfg,
            &self.store,
            &mut lin,
            &flat,
            prefixes.len(),
            None,
        )?;
        self.stats.merge(&lin.stats);
        Ok(gather_last_rows(&logits, t, &last))
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn decode_stats(&self) -> Option<DecodeStats> {
        Some(self.stats)
    }
}

/// Native-forward backend executing every quantized linear
/// **tensor-parallel** across the persistent shard workers of a
/// [`ShardedMatmul`] — the sharded counterpart of
/// [`StreamingNativeBackend`], bit-identical to it at any shard count
/// (`tests/shard_parity.rs`).
pub struct ShardedNativeBackend {
    pub cfg: ModelConfig,
    pub store: TensorStore,
    pub exec: ShardedMatmul,
    pub stats: DecodeStats,
}

impl ShardedNativeBackend {
    pub fn new(
        cfg: ModelConfig,
        store: TensorStore,
        qm: QuantizedModel,
        opts: ShardOpts,
    ) -> ShardedNativeBackend {
        ShardedNativeBackend {
            cfg,
            store,
            exec: ShardedMatmul::new(std::sync::Arc::new(qm), opts),
            stats: DecodeStats::default(),
        }
    }
}

impl LmBackend for ShardedNativeBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        Ok(self.logits_last_batch(&[tokens])?.remove(0))
    }

    fn logits_last_batch(&mut self, prefixes: &[&[i32]]) -> Result<Vec<Vec<f32>>> {
        let t = self.cfg.seq_len;
        let (flat, last) = pad_prefixes(t, prefixes);
        let mut lin = ShardedLinear {
            exec: &self.exec,
            store: &self.store,
            stats: DecodeStats::default(),
        };
        let logits = native_fwd::forward_with(
            &self.cfg,
            &self.store,
            &mut lin,
            &flat,
            prefixes.len(),
            None,
        )?;
        self.stats.merge(&lin.stats);
        Ok(gather_last_rows(&logits, t, &last))
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn decode_stats(&self) -> Option<DecodeStats> {
        Some(self.stats)
    }

    fn shard_stats(&self) -> Option<Vec<ShardStat>> {
        Some(self.exec.shard_stats())
    }
}

/// One live cached sequence inside [`CachedNativeBackend`]: the tokens it
/// has consumed so far plus its cache handle.
struct LiveSeq {
    tokens: Vec<i32>,
    id: SeqId,
}

/// Cache-aware native backend: a paged (optionally GLVQ-quantized) KV
/// cache makes decode O(T) per generated token instead of the O(T²)
/// full-prefix recompute the cacheless backends pay.
///
/// The backend recognizes lockstep stepping through the unchanged
/// [`LmBackend::logits_last_batch`] interface: a prefix that extends a
/// live sequence by exactly one token becomes a batched
/// `step_with_cache` (one incremental forward for all stepping
/// sequences); anything else — first contact with a prompt, an empty
/// prompt, or a prefix longer than `seq_len` (the sliding-window regime,
/// where cached positions shift every step) — runs a fresh prefill.
/// With f32 cache pages the produced logits are bit-identical to
/// [`NativeBackend`] / [`StreamingNativeBackend`] over the same prefixes
/// (`tests/kvcache_parity.rs`); quantized pages trade exactness for a
/// smaller resident cache. [`LmBackend::end_batch`] evicts all live
/// sequences, returning their pages to the shared arena.
pub struct CachedNativeBackend {
    cfg: ModelConfig,
    store: TensorStore,
    weights: WeightMode,
    stats: DecodeStats,
    cache: PagedKvCache,
    live: Vec<LiveSeq>,
}

/// How [`CachedNativeBackend`] applies its quantizable linears: dense
/// store, one streaming engine, or the tensor-parallel shard executor.
enum WeightMode {
    Dense,
    Streamed { qm: QuantizedModel, engine: StreamingMatmul },
    Sharded { exec: ShardedMatmul },
}

impl CachedNativeBackend {
    /// Cache-aware backend over dense weights.
    pub fn dense(cfg: ModelConfig, store: TensorStore, kv: KvCacheOpts) -> CachedNativeBackend {
        CachedNativeBackend {
            cache: PagedKvCache::new(cfg.n_layer, cfg.d_model, kv),
            cfg,
            store,
            weights: WeightMode::Dense,
            stats: DecodeStats::default(),
            live: Vec::new(),
        }
    }

    /// Cache-aware backend executing every quantized linear straight from
    /// the compressed container through the streaming engine.
    pub fn streaming(
        cfg: ModelConfig,
        store: TensorStore,
        qm: QuantizedModel,
        engine: StreamingMatmul,
        kv: KvCacheOpts,
    ) -> CachedNativeBackend {
        CachedNativeBackend {
            cache: PagedKvCache::new(cfg.n_layer, cfg.d_model, kv),
            cfg,
            store,
            weights: WeightMode::Streamed { qm, engine },
            stats: DecodeStats::default(),
            live: Vec::new(),
        }
    }

    /// Cache-aware backend executing every quantized linear
    /// **tensor-parallel** across persistent shard workers — bit-identical
    /// to [`CachedNativeBackend::streaming`] at any shard count.
    pub fn sharded(
        cfg: ModelConfig,
        store: TensorStore,
        qm: QuantizedModel,
        opts: ShardOpts,
        kv: KvCacheOpts,
    ) -> CachedNativeBackend {
        CachedNativeBackend {
            cache: PagedKvCache::new(cfg.n_layer, cfg.d_model, kv),
            cfg,
            store,
            weights: WeightMode::Sharded {
                exec: ShardedMatmul::new(std::sync::Arc::new(qm), opts),
            },
            stats: DecodeStats::default(),
            live: Vec::new(),
        }
    }

    /// Run `f` with the right [`LinearOp`] for this backend's weight mode
    /// (dense store, streamed compressed container, or sharded executor),
    /// folding decode stats back afterwards.
    fn run_cached<F>(&mut self, f: F) -> Result<Mat>
    where
        F: FnOnce(&ModelConfig, &TensorStore, &mut dyn LinearOp, &mut PagedKvCache) -> Result<Mat>,
    {
        let cfg = self.cfg;
        match &self.weights {
            WeightMode::Dense => {
                let mut lin = DenseLinear { store: &self.store };
                f(&cfg, &self.store, &mut lin, &mut self.cache)
            }
            WeightMode::Streamed { qm, engine } => {
                let mut lin = StreamedLinear {
                    qm,
                    store: &self.store,
                    engine,
                    stats: DecodeStats::default(),
                };
                let result = f(&cfg, &self.store, &mut lin, &mut self.cache);
                self.stats.merge(&lin.stats);
                result
            }
            WeightMode::Sharded { exec } => {
                let mut lin = ShardedLinear {
                    exec,
                    store: &self.store,
                    stats: DecodeStats::default(),
                };
                let result = f(&cfg, &self.store, &mut lin, &mut self.cache);
                self.stats.merge(&lin.stats);
                result
            }
        }
    }

    /// Per-shard decode counters when running sharded.
    fn shard_stats_inner(&self) -> Option<Vec<ShardStat>> {
        match &self.weights {
            WeightMode::Sharded { exec } => Some(exec.shard_stats()),
            _ => None,
        }
    }

    /// True when this backend decodes from a compressed container
    /// (streamed or sharded).
    fn serves_compressed(&self) -> bool {
        !matches!(self.weights, WeightMode::Dense)
    }

    /// Prefill one window into a fresh cache sequence; returns the handle
    /// and the last-position logits. The sequence is evicted on error.
    ///
    /// With prefix sharing on, the longest cached prefix of `tokens` is
    /// claimed first and only the remainder runs through the forward —
    /// bit-identical to the full prefill because `forward_ragged` is
    /// invariant to how a prefix is chunked (`tests/kvcache_parity.rs`).
    fn prefill_one(&mut self, tokens: &[i32]) -> Result<(SeqId, Vec<f32>)> {
        let (sid, claimed) = self.cache.new_seq_shared(tokens, tokens.len().saturating_sub(1));
        let logits = self.run_cached(|cfg, store, lin, cache| {
            if claimed == 0 {
                native_fwd::prefill_with_cache(cfg, store, lin, cache, sid, tokens)
            } else {
                native_fwd::forward_ragged(cfg, store, lin, cache, &[sid], &[&tokens[claimed..]])
            }
        });
        match logits {
            Ok(l) => Ok((sid, l.row(l.rows - 1).to_vec())),
            Err(e) => {
                self.cache.evict(sid);
                Err(e)
            }
        }
    }

    /// Model configuration (for the speculative wrapper's draft view).
    pub(crate) fn config(&self) -> ModelConfig {
        self.cfg
    }

    /// The full dense tensor store this backend was built from. Every
    /// weight mode keeps it (streamed/sharded modes still read the
    /// non-quantizable embeddings and gains from it), so the speculative
    /// wrapper can always re-quantize a draft view from here.
    pub(crate) fn tensor_store(&self) -> &TensorStore {
        &self.store
    }

    /// Roll the target KV sequence back to `rows` positions — the
    /// speculative wrapper's rejection path.
    pub(crate) fn truncate(&mut self, sid: SeqId, rows: usize) -> Result<()> {
        self.cache.truncate_seq(sid, rows)
    }
}

impl LmBackend for CachedNativeBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        Ok(self.logits_last_batch(&[tokens])?.remove(0))
    }

    fn logits_last_batch(&mut self, prefixes: &[&[i32]]) -> Result<Vec<Vec<f32>>> {
        let t_max = self.cfg.seq_len;
        let mut out: Vec<Option<Vec<f32>>> = vec![None; prefixes.len()];

        // claim step-able sequences: each live sequence serves at most one
        // prefix per call (identical concurrent prompts each get their own)
        let mut claimed = vec![false; self.live.len()];
        let mut dead = vec![false; self.live.len()];
        let mut steps: Vec<(usize, usize)> = Vec::new();
        let mut stepping = vec![false; prefixes.len()];
        for (pi, p) in prefixes.iter().enumerate() {
            let n = p.len();
            if n == 0 {
                continue;
            }
            let matched = self.live.iter().enumerate().find(|(li, s)| {
                !claimed[*li] && s.tokens.len() + 1 == n && s.tokens[..] == p[..n - 1]
            });
            if let Some((li, _)) = matched {
                claimed[li] = true;
                if n > t_max {
                    // this sequence just outgrew the position table: it can
                    // never be stepped again (the window slides from now
                    // on), so release its pages instead of leaking them
                    // until end_batch
                    dead[li] = true;
                } else {
                    steps.push((pi, li));
                    stepping[pi] = true;
                }
            }
        }
        // evict and drop dead entries *now*, before any early return can
        // leave a live entry pointing at a recycled SeqId, and so their
        // pages are reusable by the prefills below; step indices are
        // remapped into the compacted list
        if dead.iter().any(|&d| d) {
            let mut remap = vec![0usize; self.live.len()];
            let mut kept = 0usize;
            for (li, slot) in remap.iter_mut().enumerate() {
                *slot = kept;
                if dead[li] {
                    let id = self.live[li].id;
                    self.cache.evict(id);
                } else {
                    kept += 1;
                }
            }
            let mut idx = 0;
            self.live.retain(|_| {
                let keep = !dead[idx];
                idx += 1;
                keep
            });
            for s in steps.iter_mut() {
                s.1 = remap[s.1];
            }
        }

        // everything unmatched (re-)prefills: first contact, empty prompt,
        // or the sliding-window regime beyond seq_len
        for (pi, p) in prefixes.iter().enumerate() {
            if stepping[pi] {
                continue;
            }
            let window: &[i32] = if p.is_empty() {
                &[0]
            } else if p.len() > t_max {
                &p[p.len() - t_max..]
            } else {
                p
            };
            let (sid, logits) = self.prefill_one(window)?;
            if p.is_empty() || p.len() > t_max {
                // the cache cannot extend this prefix next step, so the
                // window is transient — release its pages immediately
                self.cache.evict(sid);
            } else {
                self.live.push(LiveSeq { tokens: p.to_vec(), id: sid });
            }
            out[pi] = Some(logits);
        }

        // one batched incremental forward advances all stepping sequences
        if !steps.is_empty() {
            let ids: Vec<SeqId> = steps.iter().map(|&(_, li)| self.live[li].id).collect();
            let last: Vec<i32> =
                steps.iter().map(|&(pi, _)| *prefixes[pi].last().unwrap()).collect();
            let stepped = self.run_cached(|cfg, store, lin, cache| {
                native_fwd::step_with_cache(cfg, store, lin, cache, &ids, &last)
            });
            let logits = match stepped {
                Ok(l) => l,
                Err(e) => {
                    // a failed batched step (e.g. arena exhaustion part-way
                    // through a layer) leaves the stepping sequences with
                    // skewed per-layer row counts — evict and drop them so
                    // a retry re-prefills instead of silently mixing
                    // misaligned K/V
                    let mut bad = vec![false; self.live.len()];
                    for &(_, li) in &steps {
                        bad[li] = true;
                        let id = self.live[li].id;
                        self.cache.evict(id);
                    }
                    let mut idx = 0;
                    self.live.retain(|_| {
                        let keep = !bad[idx];
                        idx += 1;
                        keep
                    });
                    return Err(e);
                }
            };
            for (si, &(pi, li)) in steps.iter().enumerate() {
                // the claim already verified tokens == prefix[..n-1], so
                // advancing is a single O(1) push, not an O(T) clone
                self.live[li].tokens.push(last[si]);
                out[pi] = Some(logits.row(si).to_vec());
            }
        }

        Ok(out.into_iter().map(|o| o.expect("every prefix answered")).collect())
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn decode_stats(&self) -> Option<DecodeStats> {
        self.serves_compressed().then_some(self.stats)
    }

    fn end_batch(&mut self) {
        for s in self.live.drain(..) {
            // publish before evicting: the departing sequence's pages
            // survive as a cold shared prefix the next batch (or the next
            // session turn) claims instead of re-prefilling
            self.cache.publish_prefix(s.id, &s.tokens);
            self.cache.evict(s.id);
        }
    }

    fn cache_stats(&self) -> Option<KvCacheStats> {
        Some(self.cache.stats())
    }

    fn shard_stats(&self) -> Option<Vec<ShardStat>> {
        self.shard_stats_inner()
    }
}

/// The continuous scheduler's per-sequence hooks: the lockstep loop
/// drives this backend through the all-or-nothing `logits_last_batch`
/// recognition, while `serving::ContinuousScheduler` owns sequence
/// identity explicitly and schedules through these.
impl SeqBackend for CachedNativeBackend {
    fn ctx_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn begin_seq(&mut self) -> SeqId {
        self.cache.new_seq()
    }

    fn begin_seq_prefixed(&mut self, tokens: &[i32], max_rows: usize) -> (SeqId, usize) {
        self.cache.new_seq_shared(tokens, max_rows)
    }

    fn publish_seq(&mut self, sid: SeqId, tokens: &[i32]) {
        self.cache.publish_prefix(sid, tokens);
    }

    fn step_ragged(&mut self, items: &[(SeqId, &[i32])]) -> Result<Mat> {
        let seqs: Vec<SeqId> = items.iter().map(|it| it.0).collect();
        let toks: Vec<&[i32]> = items.iter().map(|it| it.1).collect();
        self.run_cached(|cfg, store, lin, cache| {
            native_fwd::forward_ragged(cfg, store, lin, cache, &seqs, &toks)
        })
    }

    fn retire_seq(&mut self, sid: SeqId) {
        self.cache.evict(sid);
    }

    fn preempt_seq(&mut self, sid: SeqId, quantize: bool) -> Result<SpilledSeq> {
        self.cache.spill(sid, quantize)
    }

    fn resume_seq(&mut self, sp: SpilledSeq) -> std::result::Result<SeqId, SpilledSeq> {
        self.cache.restore(sp)
    }

    fn free_pages(&self) -> Option<usize> {
        self.cache.free_pages()
    }

    fn page_capacity(&self) -> Option<usize> {
        self.cache.page_capacity()
    }

    fn pages_for(&self, rows: usize, n_new: usize) -> usize {
        self.cache.pages_needed(rows, n_new)
    }

    fn kv_stats(&self) -> Option<KvCacheStats> {
        Some(self.cache.stats())
    }

    fn stream_stats(&self) -> Option<DecodeStats> {
        self.serves_compressed().then_some(self.stats)
    }

    fn sharded_stats(&self) -> Option<Vec<ShardStat>> {
        self.shard_stats_inner()
    }
}

/// PJRT backend over the logits artifact.
pub struct PjrtBackend {
    exec: LogitsExec,
    params: Vec<crate::runtime::exec::StagedBuf>,
}

impl PjrtBackend {
    pub fn new(engine: &Engine, model: &str, store: &TensorStore) -> Result<PjrtBackend> {
        let exec = LogitsExec::new(engine, model)?;
        let params = exec.stage_params(store)?;
        Ok(PjrtBackend { exec, params })
    }
}

impl LmBackend for PjrtBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let t = self.exec.seq;
        let keep = tokens.len().min(t);
        let mut x = tokens[tokens.len() - keep..].to_vec();
        let last = keep.max(1) - 1;
        x.resize(t, 0);
        let logits = self.exec.logits(&self.params, &x)?;
        let v = self.exec.vocab;
        Ok(logits[last * v..(last + 1) * v].to_vec())
    }

    fn seq_len(&self) -> usize {
        self.exec.seq
    }

    fn vocab(&self) -> usize {
        self.exec.vocab
    }
}

/// A client request.
#[derive(Clone, Debug)]
pub enum Request {
    /// greedy-decode `max_new` bytes after the prompt
    Generate { prompt: Vec<u8>, max_new: usize },
    /// total log P(continuation | prompt)
    Score { prompt: Vec<u8>, continuation: Vec<u8> },
}

/// The server's answer.
#[derive(Clone, Debug)]
pub enum Response {
    Generated { text: Vec<u8> },
    Scored { logprob: f64 },
    /// The request was accepted but failed while running.
    Error { message: String },
    /// The request was refused at admission (continuous mode): the
    /// `reason` is the rendered [`crate::serving::Backpressure`] —
    /// bounded-queue overflow, token-budget overflow, context overflow,
    /// or a KV footprint the arena can never hold. Shed load or retry.
    Rejected { reason: String },
}

struct Job {
    request: Request,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
    /// when present, the request's recorded [`RequestTimeline`] is sent
    /// here just before the response — the observability side-channel of
    /// [`ServerHandle::submit_timed`]
    timeline_reply: Option<mpsc::Sender<RequestTimeline>>,
}

/// Handle used by clients to submit requests.
///
/// Also the home of **multi-turn sessions**: [`ServerHandle::begin_session`]
/// opens a transcript, [`ServerHandle::continue_session`] replays it as the
/// prompt prefix of each turn and folds the response back in. Sessions are
/// a pure client-side protocol over [`Request::Generate`] — they work
/// against both the lockstep and the continuous loop — and when the
/// backend runs with [`KvCacheOpts::prefix_share`], every turn's replayed
/// transcript is claimed from the shared KV prefix instead of re-prefilled.
pub struct ServerHandle {
    tx: mpsc::Sender<Job>,
    join: Option<std::thread::JoinHandle<ServerMetrics>>,
    sessions: Mutex<BTreeMap<u64, Vec<u8>>>,
    next_session: AtomicU64,
}

impl ServerHandle {
    /// Submit a request; returns the response receiver.
    pub fn submit(&self, request: Request) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Job {
            request,
            reply,
            submitted: Instant::now(),
            timeline_reply: None,
        });
        rx
    }

    /// Submit a request and additionally receive its recorded
    /// [`RequestTimeline`] — the submit → admit → prefill → first-token →
    /// decode → finish lifecycle with queue/prefill/decode attribution
    /// ([`crate::obs::Breakdown`]). The timeline is sent just before the
    /// response, so once the response arrives the timeline receiver never
    /// blocks. Requests rejected at admission in continuous mode get a
    /// minimal timeline (submit → finish, all queue time, rid 0).
    pub fn submit_timed(
        &self,
        request: Request,
    ) -> (mpsc::Receiver<Response>, mpsc::Receiver<RequestTimeline>) {
        let (reply, rx) = mpsc::channel();
        let (ttx, trx) = mpsc::channel();
        let _ = self.tx.send(Job {
            request,
            reply,
            submitted: Instant::now(),
            timeline_reply: Some(ttx),
        });
        (rx, trx)
    }

    /// Convenience: submit and wait.
    pub fn call(&self, request: Request) -> Result<Response> {
        self.submit(request).recv().context("server dropped the reply")
    }

    /// Open a multi-turn session seeded with `system` (the shared system
    /// prompt). Returns the session id for
    /// [`ServerHandle::continue_session`].
    pub fn begin_session(&self, system: &[u8]) -> u64 {
        let sid = self.next_session.fetch_add(1, Ordering::Relaxed);
        self.sessions.lock().expect("session store poisoned").insert(sid, system.to_vec());
        sid
    }

    /// Run one session turn: append `user` to the transcript, generate up
    /// to `max_new` bytes conditioned on the whole transcript, and fold
    /// the generated bytes back in for the next turn. The transcript *is*
    /// the prompt, so with prefix sharing on the backend claims every
    /// previous turn's KV from the cache and prefills only the new bytes.
    pub fn continue_session(&self, sid: u64, user: &[u8], max_new: usize) -> Result<Response> {
        let prompt = {
            let mut sessions = self.sessions.lock().expect("session store poisoned");
            let t = sessions.get_mut(&sid).context("unknown session id")?;
            t.extend_from_slice(user);
            t.clone()
        };
        let resp = self.call(Request::Generate { prompt, max_new })?;
        if let Response::Generated { text } = &resp {
            let mut sessions = self.sessions.lock().expect("session store poisoned");
            if let Some(t) = sessions.get_mut(&sid) {
                t.extend_from_slice(text);
            }
        }
        Ok(resp)
    }

    /// Close a session, returning its final transcript (None for an
    /// unknown id).
    pub fn end_session(&self, sid: u64) -> Option<Vec<u8>> {
        self.sessions.lock().expect("session store poisoned").remove(&sid)
    }

    /// Stop the worker and return final metrics.
    pub fn shutdown(mut self) -> ServerMetrics {
        drop(self.tx);
        self.join
            .take()
            .expect("shutdown called once")
            .join()
            .expect("server thread panicked")
    }
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// max requests drained into one processing batch
    pub max_batch: usize,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts { max_batch: 8 }
    }
}

/// Start the serving loop on its own thread. `make_backend` runs inside the
/// worker thread (PJRT clients/executables are thread-local).
pub fn start<F>(make_backend: F, opts: ServerOpts) -> ServerHandle
where
    F: FnOnce() -> Result<Box<dyn LmBackend>> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Job>();
    let join = std::thread::spawn(move || {
        let mut backend = make_backend().expect("backend construction failed");
        let mut metrics = ServerMetrics::default();
        let mut next_rid: u64 = 0;
        loop {
            // block for the first job, then drain up to max_batch
            let first = match rx.recv() {
                Ok(j) => j,
                Err(_) => break, // all senders dropped → shutdown
            };
            let mut batch = vec![first];
            while batch.len() < opts.max_batch {
                match rx.try_recv() {
                    Ok(j) => batch.push(j),
                    Err(_) => break,
                }
            }
            metrics.batches += 1;
            for job in &batch {
                metrics
                    .queue_wait
                    .record(job.submitted.elapsed().as_secs_f64() * 1e3);
            }
            // borrow the payloads: a drained batch steps against the jobs
            // it came from, so nothing needs the prompt bytes cloned
            let requests: Vec<&Request> = batch.iter().map(|j| &j.request).collect();
            let submitted: Vec<Instant> = batch.iter().map(|j| j.submitted).collect();
            // lockstep has no admission control or chunked prefill, so its
            // timelines carry only queue (submit → drain) vs in-batch time
            let mut timelines: Vec<RequestTimeline> = batch
                .iter()
                .map(|job| {
                    next_rid += 1;
                    let base_ns = crate::obs::span::now_ns()
                        .saturating_sub(job.submitted.elapsed().as_nanos() as u64);
                    let mut t = RequestTimeline::with_base(next_rid, base_ns);
                    t.mark(Mark::Admit);
                    t
                })
                .collect();
            let responses = {
                let _sp = crate::span!("lockstep_batch");
                handle_batch(&mut *backend, &requests, &submitted, &mut metrics)
            };
            for ((job, response), mut timeline) in
                batch.into_iter().zip(responses).zip(timelines.drain(..))
            {
                metrics.requests += 1;
                metrics
                    .latency
                    .record(job.submitted.elapsed().as_secs_f64() * 1e3);
                timeline.mark(Mark::Finish);
                if let Some(ttx) = job.timeline_reply {
                    let _ = ttx.send(timeline.clone());
                }
                const MAX_TIMELINES: usize = 16_384;
                if metrics.timelines.len() < MAX_TIMELINES {
                    metrics.timelines.push(timeline);
                }
                let _ = job.reply.send(response);
            }
        }
        // metrics are only observable at shutdown (the join below), so
        // the backend counters are snapshotted once here, not per batch
        metrics.decode = backend.decode_stats();
        metrics.kv_cache = backend.cache_stats();
        metrics.shards = backend.shard_stats();
        metrics.spec = backend.spec_stats();
        metrics
    });
    ServerHandle {
        tx,
        join: Some(join),
        sessions: Mutex::new(BTreeMap::new()),
        next_session: AtomicU64::new(1),
    }
}

/// Start the **continuous-batching** serving loop on its own thread: the
/// same [`ServerHandle`] interface as [`start`], but requests feed the
/// admission-controlled queue of a [`ContinuousScheduler`] instead of
/// lockstep batches — sequences join and leave the step batch per token,
/// long prompts prefill in `prefill_chunk`-token slices, finished
/// sequences free their KV pages immediately, and page pressure preempts
/// (quantize-to-spill) rather than erroring. Requests the scheduler
/// refuses come back as [`Response::Rejected`] with the structured
/// backpressure reason.
///
/// Requires a cache-aware backend: continuous scheduling *is* paged-KV
/// bookkeeping, so `make_backend` returns a [`SeqBackend`] — typically a
/// [`CachedNativeBackend`] (dense or streamed-compressed weights), or a
/// [`crate::spec::SpeculativeBackend`] wrapping one.
pub fn start_continuous<B, F>(make_backend: F, opts: ContinuousOpts) -> ServerHandle
where
    B: SeqBackend + Send + 'static,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Job>();
    let join = std::thread::spawn(move || {
        let backend = make_backend().expect("backend construction failed");
        let mut sched = ContinuousScheduler::new(backend, opts);
        let mut replies: BTreeMap<u64, mpsc::Sender<Response>> = BTreeMap::new();
        let mut timeline_txs: BTreeMap<u64, mpsc::Sender<RequestTimeline>> = BTreeMap::new();
        let mut open = true;
        while open || sched.has_work() {
            // pull in everything that has arrived; block only when idle
            if sched.has_work() {
                loop {
                    match rx.try_recv() {
                        Ok(job) => submit_job(&mut sched, &mut replies, &mut timeline_txs, job),
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
                sched.step();
            } else {
                match rx.recv() {
                    Ok(job) => submit_job(&mut sched, &mut replies, &mut timeline_txs, job),
                    Err(_) => open = false,
                }
            }
            for (rid, response) in sched.drain_finished() {
                // timeline first: once the response arrives at a
                // submit_timed caller, the timeline is already queued
                if let Some(ttx) = timeline_txs.remove(&rid) {
                    if let Some(t) = sched.timeline_for(rid) {
                        let _ = ttx.send(t);
                    }
                }
                if let Some(reply) = replies.remove(&rid) {
                    let _ = reply.send(response);
                }
            }
        }
        sched.into_metrics()
    });
    ServerHandle {
        tx,
        join: Some(join),
        sessions: Mutex::new(BTreeMap::new()),
        next_session: AtomicU64::new(1),
    }
}

/// Feed one job into the scheduler, answering immediately-refused
/// requests with their structured backpressure reason.
fn submit_job<B: SeqBackend>(
    sched: &mut ContinuousScheduler<B>,
    replies: &mut BTreeMap<u64, mpsc::Sender<Response>>,
    timeline_txs: &mut BTreeMap<u64, mpsc::Sender<RequestTimeline>>,
    job: Job,
) {
    match sched.submit(job.request, job.submitted) {
        Ok(rid) => {
            replies.insert(rid, job.reply);
            if let Some(ttx) = job.timeline_reply {
                timeline_txs.insert(rid, ttx);
            }
        }
        Err(bp) => {
            if let Some(ttx) = job.timeline_reply {
                // refused before admission: the whole lifetime is queue
                // time and the request never got a scheduler id
                let base_ns = crate::obs::span::now_ns()
                    .saturating_sub(job.submitted.elapsed().as_nanos() as u64);
                let mut t = RequestTimeline::with_base(0, base_ns);
                t.mark(Mark::Finish);
                let _ = ttx.send(t);
            }
            let _ = job.reply.send(Response::Rejected { reason: bp.to_string() });
        }
    }
}

/// Per-request lockstep state: both kinds only ever need last-position
/// logits of their current prefix, so generates and scores share batches.
enum SeqState {
    Gen { tokens: Vec<i32>, start: usize, max_new: usize },
    Score { tokens: Vec<i32>, continuation: Vec<u8>, pos: usize, logprob: f64 },
    Failed { message: String },
}

impl SeqState {
    fn active(&self) -> bool {
        match self {
            SeqState::Gen { tokens, start, max_new } => tokens.len() - start < *max_new,
            SeqState::Score { continuation, pos, .. } => *pos < continuation.len(),
            SeqState::Failed { .. } => false,
        }
    }
}

/// Answer one drained batch: every step gathers the prefixes of all still-
/// active requests into a single `logits_last_batch` call, then advances
/// each by one token. Deterministic and equivalent to serving the requests
/// one at a time (the native forward treats batch rows independently).
/// Requests are borrowed — the lockstep loop never clones prompt bytes —
/// and `submitted` (parallel to `requests`) feeds the time-to-first-token
/// histogram.
fn handle_batch(
    backend: &mut dyn LmBackend,
    requests: &[&Request],
    submitted: &[Instant],
    metrics: &mut ServerMetrics,
) -> Vec<Response> {
    debug_assert_eq!(requests.len(), submitted.len());
    let mut states: Vec<SeqState> = requests
        .iter()
        .map(|&r| match r {
            Request::Generate { prompt, max_new } => {
                let tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
                let start = tokens.len();
                SeqState::Gen { tokens, start, max_new: *max_new }
            }
            Request::Score { prompt, continuation } => SeqState::Score {
                tokens: prompt.iter().map(|&b| b as i32).collect(),
                continuation: continuation.clone(),
                pos: 0,
                logprob: 0.0,
            },
        })
        .collect();
    let mut saw_first = vec![false; states.len()];

    loop {
        let active: Vec<usize> = (0..states.len()).filter(|&i| states[i].active()).collect();
        if active.is_empty() {
            break;
        }
        let prefixes: Vec<&[i32]> = active
            .iter()
            .map(|&i| match &states[i] {
                SeqState::Gen { tokens, .. } | SeqState::Score { tokens, .. } => {
                    tokens.as_slice()
                }
                SeqState::Failed { .. } => unreachable!("failed sequences are inactive"),
            })
            .collect();
        let stepped = backend.logits_last_batch(&prefixes);
        drop(prefixes); // release the &states borrows before mutating below
        let all_logits = match stepped {
            Ok(l) => l,
            Err(e) => {
                let message = e.to_string();
                for &i in &active {
                    states[i] = SeqState::Failed { message: message.clone() };
                }
                break;
            }
        };
        for (&i, logits) in active.iter().zip(&all_logits) {
            if !saw_first[i] {
                saw_first[i] = true;
                metrics.ttft.record(submitted[i].elapsed().as_secs_f64() * 1e3);
            }
            match &mut states[i] {
                SeqState::Gen { tokens, .. } => {
                    tokens.push(native_fwd::argmax_logit(logits));
                    metrics.tokens_out += 1;
                }
                SeqState::Score { tokens, continuation, pos, logprob } => {
                    let b = continuation[*pos];
                    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                    let lse: f32 =
                        logits.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
                    *logprob += (logits[b as usize] - lse) as f64;
                    tokens.push(b as i32);
                    *pos += 1;
                    metrics.tokens_out += 1;
                }
                SeqState::Failed { .. } => unreachable!("failed sequences are inactive"),
            }
        }
    }

    // the drained batch is complete: let cache-aware backends release
    // their per-sequence state (pages return to the shared arena)
    backend.end_batch();

    states
        .into_iter()
        .map(|s| match s {
            SeqState::Gen { tokens, start, .. } => Response::Generated {
                text: tokens[start..].iter().map(|&t| t.clamp(0, 255) as u8).collect(),
            },
            SeqState::Score { logprob, .. } => Response::Scored { logprob },
            SeqState::Failed { message } => Response::Error { message },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::eval::native_fwd::CalibCapture;
    use crate::glvq::pipeline::{quantize_model, PipelineOpts};
    use crate::model::{init_params, ModelConfig};
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t",
            vocab: 256,
            d_model: 32,
            n_layer: 1,
            n_head: 2,
            d_ff: 64,
            seq_len: 32,
            batch_train: 2,
            batch_eval: 2,
        }
    }

    fn tiny_backend() -> Result<Box<dyn LmBackend>> {
        let cfg = tiny_cfg();
        let store = init_params(&cfg, 0);
        Ok(Box::new(NativeBackend { cfg, store }))
    }

    /// Drive one lockstep batch over owned requests (the tests' shorthand
    /// for the borrow-based [`handle_batch`]).
    fn run_batch(
        backend: &mut dyn LmBackend,
        requests: &[Request],
        metrics: &mut ServerMetrics,
    ) -> Vec<Response> {
        let refs: Vec<&Request> = requests.iter().collect();
        let submitted = vec![Instant::now(); requests.len()];
        handle_batch(backend, &refs, &submitted, metrics)
    }

    /// Quantize the tiny model with RTN and wrap it in the compressed-
    /// weights streaming backend.
    fn tiny_streaming_backend(threads: usize) -> Result<Box<dyn LmBackend>> {
        let cfg = tiny_cfg();
        let store = init_params(&cfg, 0);
        let mut rng = Rng::new(5);
        let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
        let mut cap = CalibCapture::new(16, 0);
        native_fwd::forward(&cfg, &store, &toks, 2, Some(&mut cap))?;
        let calib = cap.into_calib_set();
        let mut opts = PipelineOpts::default();
        opts.target_bits = 3.0;
        opts.bit_allocation = false;
        let (qm, _) = quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts)?;
        Ok(Box::new(StreamingNativeBackend {
            cfg,
            store,
            qm,
            engine: StreamingMatmul::new(8, threads),
            stats: DecodeStats::default(),
        }))
    }

    #[test]
    fn generate_and_score_roundtrip() {
        let handle = start(tiny_backend, ServerOpts::default());
        match handle.call(Request::Generate { prompt: b"the kama ".to_vec(), max_new: 5 }).unwrap()
        {
            Response::Generated { text } => assert_eq!(text.len(), 5),
            other => panic!("unexpected {other:?}"),
        }
        match handle
            .call(Request::Score { prompt: b"the ".to_vec(), continuation: b"ka".to_vec() })
            .unwrap()
        {
            Response::Scored { logprob } => assert!(logprob < 0.0 && logprob.is_finite()),
            other => panic!("unexpected {other:?}"),
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 2);
        assert_eq!(metrics.tokens_out, 7);
        assert!(metrics.latency.quantile(0.5) >= 0.0);
        assert!(metrics.decode.is_none(), "dense backend reports no decode stats");
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let handle = start(tiny_backend, ServerOpts { max_batch: 4 });
        let receivers: Vec<_> = (0..10)
            .map(|i| {
                handle.submit(Request::Generate {
                    prompt: format!("req {i} ").into_bytes(),
                    max_new: 2,
                })
            })
            .collect();
        for rx in receivers {
            match rx.recv().unwrap() {
                Response::Generated { text } => assert_eq!(text.len(), 2),
                other => panic!("unexpected {other:?}"),
            }
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 10);
        assert!(metrics.batches <= 10);
    }

    #[test]
    fn deterministic_generation() {
        let h1 = start(tiny_backend, ServerOpts::default());
        let h2 = start(tiny_backend, ServerOpts::default());
        let r1 = h1.call(Request::Generate { prompt: b"abc".to_vec(), max_new: 4 }).unwrap();
        let r2 = h2.call(Request::Generate { prompt: b"abc".to_vec(), max_new: 4 }).unwrap();
        match (r1, r2) {
            (Response::Generated { text: a }, Response::Generated { text: b }) => {
                assert_eq!(a, b)
            }
            _ => panic!(),
        }
        h1.shutdown();
        h2.shutdown();
    }

    #[test]
    fn batched_lockstep_equals_sequential() {
        // the same mixed generate/score workload must produce identical
        // answers whether it is served one request per batch or all at once
        let requests = vec![
            Request::Generate { prompt: b"the kama ".to_vec(), max_new: 4 },
            Request::Score { prompt: b"the ".to_vec(), continuation: b"ka".to_vec() },
            Request::Generate { prompt: b"Boku ".to_vec(), max_new: 2 },
        ];
        let cfg = tiny_cfg();
        let store = init_params(&cfg, 0);
        let mut b1 = NativeBackend { cfg, store };
        let mut m1 = ServerMetrics::default();
        let sequential: Vec<Response> = requests
            .iter()
            .map(|r| run_batch(&mut b1, std::slice::from_ref(r), &mut m1).remove(0))
            .collect();
        let cfg = tiny_cfg();
        let store = init_params(&cfg, 0);
        let mut b2 = NativeBackend { cfg, store };
        let mut m2 = ServerMetrics::default();
        let batched = run_batch(&mut b2, &requests, &mut m2);
        assert_eq!(m1.tokens_out, m2.tokens_out);
        for (a, b) in sequential.iter().zip(&batched) {
            match (a, b) {
                (Response::Generated { text: ta }, Response::Generated { text: tb }) => {
                    assert_eq!(ta, tb)
                }
                (Response::Scored { logprob: la }, Response::Scored { logprob: lb }) => {
                    assert!((la - lb).abs() < 1e-9, "{la} vs {lb}")
                }
                other => panic!("mismatched kinds {other:?}"),
            }
        }
    }

    #[test]
    fn streaming_backend_serves_without_full_dequantize() {
        let handle = start(|| tiny_streaming_backend(2), ServerOpts { max_batch: 4 });
        let receivers: Vec<_> = (0..4)
            .map(|i| {
                if i % 2 == 0 {
                    handle.submit(Request::Generate {
                        prompt: format!("req {i} ").into_bytes(),
                        max_new: 3,
                    })
                } else {
                    handle.submit(Request::Score {
                        prompt: b"the ".to_vec(),
                        continuation: b"ka".to_vec(),
                    })
                }
            })
            .collect();
        for rx in receivers {
            match rx.recv().unwrap() {
                Response::Generated { text } => assert_eq!(text.len(), 3),
                Response::Scored { logprob } => assert!(logprob.is_finite()),
                other => panic!("unexpected {other:?}"),
            }
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 4);
        let stats = metrics.decode.expect("streaming backend reports decode stats");
        assert!(stats.code_bytes > 0 && stats.weights_decoded > 0);
        // the acceptance bound: peak decoded working set ≤ panel_rows × n_in
        // (panel_rows = 8, max n_in = d_ff = 64), never a full layer
        assert!(stats.peak_decoded <= 8 * 64, "peak {} elems", stats.peak_decoded);
        assert!(stats.peak_decoded < 32 * 32, "full layer materialized");
    }

    #[test]
    fn cached_backend_matches_uncached_lockstep_exactly() {
        // the cache-aware backend must answer a mixed generate/score batch
        // with the same bytes and logprobs as the cacheless backend — the
        // f32 KV cache is a pure speedup, not an approximation
        let requests = vec![
            Request::Generate { prompt: b"the kama ".to_vec(), max_new: 5 },
            Request::Score { prompt: b"the ".to_vec(), continuation: b"kam".to_vec() },
            Request::Generate { prompt: b"the kama ".to_vec(), max_new: 5 }, // duplicate prompt
            Request::Generate { prompt: Vec::new(), max_new: 3 },            // empty prompt
        ];
        let cfg = tiny_cfg();
        let mut plain = NativeBackend { cfg, store: init_params(&cfg, 0) };
        let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut cached = CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv);
        let mut m1 = ServerMetrics::default();
        let mut m2 = ServerMetrics::default();
        let a = run_batch(&mut plain, &requests, &mut m1);
        let b = run_batch(&mut cached, &requests, &mut m2);
        assert_eq!(m1.tokens_out, m2.tokens_out);
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (Response::Generated { text: tx }, Response::Generated { text: ty }) => {
                    assert_eq!(tx, ty, "cached generation diverged")
                }
                (Response::Scored { logprob: lx }, Response::Scored { logprob: ly }) => {
                    assert!((lx - ly).abs() < 1e-12, "{lx} vs {ly}")
                }
                other => panic!("mismatched kinds {other:?}"),
            }
        }
        // end_batch ran inside handle_batch: all pages are back on the
        // free list, but the peak shows the batch actually used the cache
        let stats = cached.cache_stats().expect("cached backend reports stats");
        assert_eq!(stats.pages_in_use, 0);
        assert!(stats.peak_pages > 0);
        assert!(stats.appended_rows > 0);
        assert!(plain.decode_stats().is_none());
    }

    #[test]
    fn cached_backend_slides_the_window_beyond_seq_len() {
        // prefixes longer than seq_len fall back to windowed recompute and
        // must still match the cacheless backend bit for bit
        let cfg = tiny_cfg(); // seq_len 32
        let mut plain = NativeBackend { cfg, store: init_params(&cfg, 0) };
        let kv = KvCacheOpts { page_rows: 8, ..Default::default() };
        let mut cached = CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv);
        let req = [Request::Generate { prompt: b"a long running prompt ".to_vec(), max_new: 20 }];
        let mut m = ServerMetrics::default();
        let a = run_batch(&mut plain, &req, &mut m).remove(0);
        let b = run_batch(&mut cached, &req, &mut m).remove(0);
        match (a, b) {
            (Response::Generated { text: ta }, Response::Generated { text: tb }) => {
                assert_eq!(ta.len(), 20);
                assert_eq!(ta, tb, "windowed regime diverged")
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn cached_streaming_backend_matches_streaming_generation() {
        // compressed weights + KV cache together must still generate the
        // same bytes as the cacheless streaming backend
        let cfg = tiny_cfg();
        let store = init_params(&cfg, 0);
        let mut rng = Rng::new(5);
        let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
        let mut cap = CalibCapture::new(16, 0);
        native_fwd::forward(&cfg, &store, &toks, 2, Some(&mut cap)).unwrap();
        let calib = cap.into_calib_set();
        let mut opts = PipelineOpts::default();
        opts.target_bits = 3.0;
        opts.bit_allocation = false;
        let (qm, _) =
            quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).unwrap();

        let mut plain = StreamingNativeBackend {
            cfg,
            store: store.clone(),
            qm: qm.clone(),
            engine: StreamingMatmul::new(8, 2),
            stats: DecodeStats::default(),
        };
        let kv = KvCacheOpts { page_rows: 8, ..Default::default() };
        let mut cached =
            CachedNativeBackend::streaming(cfg, store, qm, StreamingMatmul::new(8, 2), kv);
        let req = [
            Request::Generate { prompt: b"the kama ".to_vec(), max_new: 6 },
            Request::Score { prompt: b"the ".to_vec(), continuation: b"ka".to_vec() },
        ];
        let mut m = ServerMetrics::default();
        let a = run_batch(&mut plain, &req, &mut m);
        let b = run_batch(&mut cached, &req, &mut m);
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (Response::Generated { text: tx }, Response::Generated { text: ty }) => {
                    assert_eq!(tx, ty)
                }
                (Response::Scored { logprob: lx }, Response::Scored { logprob: ly }) => {
                    assert!((lx - ly).abs() < 1e-12)
                }
                other => panic!("mismatched kinds {other:?}"),
            }
        }
        let stats = cached.decode_stats().expect("streamed cached backend reports decode stats");
        assert!(stats.code_bytes > 0 && stats.weights_decoded > 0);
        assert!(cached.cache_stats().is_some());
    }

    #[test]
    fn quantized_kv_serves_through_the_server() {
        // end-to-end: quantized KV pages behind the full server loop —
        // responses arrive, metrics expose quantization + decode counters
        let cfg = tiny_cfg();
        let kv = KvCacheOpts {
            page_rows: 4,
            quantize: true,
            kv_bits: 8,
            ..Default::default()
        };
        let handle = start(
            move || {
                let backend = CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv);
                Ok(Box::new(backend) as Box<_>)
            },
            ServerOpts { max_batch: 4 },
        );
        let receivers: Vec<_> = (0..4)
            .map(|i| {
                handle.submit(Request::Generate {
                    prompt: format!("req {i} ").into_bytes(),
                    max_new: 8,
                })
            })
            .collect();
        for rx in receivers {
            match rx.recv().unwrap() {
                Response::Generated { text } => assert_eq!(text.len(), 8),
                other => panic!("unexpected {other:?}"),
            }
        }
        let metrics = handle.shutdown();
        let stats = metrics.kv_cache.expect("cache-aware backend reports kv stats");
        assert!(stats.pages_quantized > 0, "retired pages should be quantized");
        assert!(stats.decoded_bytes > 0, "attention reads should decode pages");
        assert!(stats.peak_pages > 0);
        assert!(metrics.report().contains("kv_pages"));
    }

    #[test]
    fn sessions_resume_their_transcript_and_share_the_prefix() {
        // the same two-turn session against sharing-off and sharing-on
        // backends: identical bytes (f32 sharing is exact), and the
        // sharing run claims the transcript instead of re-prefilling it
        let cfg = tiny_cfg();
        let run = |kv: KvCacheOpts| {
            let handle = start(
                move || {
                    Ok(Box::new(CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv))
                        as Box<dyn LmBackend>)
                },
                ServerOpts::default(),
            );
            let sid = handle.begin_session(b"sys: ");
            let mut texts = Vec::new();
            for user in [b"aa".as_slice(), b"bb"] {
                match handle.continue_session(sid, user, 3).unwrap() {
                    Response::Generated { text } => texts.push(text),
                    other => panic!("unexpected {other:?}"),
                }
            }
            let transcript = handle.end_session(sid).expect("open session");
            (texts, transcript, handle.shutdown())
        };
        let (t_off, tr_off, _) =
            run(KvCacheOpts { page_rows: 4, ..Default::default() });
        let (t_on, tr_on, m_on) =
            run(KvCacheOpts { page_rows: 4, prefix_share: true, ..Default::default() });
        assert_eq!(t_off, t_on, "prefix sharing must not change generated bytes");
        assert_eq!(tr_off, tr_on);
        // transcript = system + both user turns + both 3-byte responses
        assert_eq!(tr_on.len(), 5 + 2 + 3 + 2 + 3);
        let kv = m_on.kv_cache.expect("cached backend reports kv stats");
        assert!(kv.prefix_hits >= 1, "turn 2 claims turn 1's published prefix");
        assert!(kv.prefix_hit_rows >= 5, "system + first turn rows come from the cache");
        assert!(kv.shared_nodes >= 2, "the final transcript stays published");
        let snap = m_on.snapshot();
        assert!(snap.counter("kv_prefix_hits_total") >= 1);
        assert!(m_on.report().contains("prefix_hit_rate"));
    }

    #[test]
    fn continuous_server_roundtrip_mixed_requests() {
        // the continuous path behind the unchanged ServerHandle surface:
        // mixed generate/score traffic all answered, scheduler metrics on
        let cfg = tiny_cfg();
        let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
        let handle = start_continuous(
            move || Ok(CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv)),
            ContinuousOpts { prefill_chunk: 4, ..Default::default() },
        );
        let mut rxs = Vec::new();
        for i in 0..6 {
            if i % 3 == 2 {
                rxs.push(handle.submit(Request::Score {
                    prompt: b"the ".to_vec(),
                    continuation: b"ka".to_vec(),
                }));
            } else {
                rxs.push(handle.submit(Request::Generate {
                    prompt: format!("req {i} ").into_bytes(),
                    max_new: 5,
                }));
            }
        }
        for rx in rxs {
            match rx.recv().unwrap() {
                Response::Generated { text } => assert_eq!(text.len(), 5),
                Response::Scored { logprob } => assert!(logprob.is_finite()),
                other => panic!("unexpected {other:?}"),
            }
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 6);
        assert!(metrics.sched_steps > 0, "continuous mode counts scheduler steps");
        assert_eq!(metrics.ttft.count(), 6);
        assert!(metrics.kv_cache.is_some());
    }

    #[test]
    fn continuous_server_rejects_with_structured_backpressure() {
        let cfg = tiny_cfg(); // seq_len 32
        let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
        let handle = start_continuous(
            move || Ok(CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv)),
            ContinuousOpts::default(),
        );
        // prompt + max_new exceeds the model context → structured refusal
        match handle.call(Request::Generate { prompt: vec![b'x'; 30], max_new: 10 }).unwrap() {
            Response::Rejected { reason } => assert!(reason.contains("context"), "{reason}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match handle.call(Request::Generate { prompt: Vec::new(), max_new: 3 }).unwrap() {
            Response::Rejected { reason } => assert!(reason.contains("prompt"), "{reason}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        // a feasible request still succeeds on the same handle
        match handle.call(Request::Generate { prompt: b"ok ".to_vec(), max_new: 3 }).unwrap() {
            Response::Generated { text } => assert_eq!(text.len(), 3),
            other => panic!("unexpected {other:?}"),
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 1, "rejected requests never reach the model");
    }

    #[test]
    fn timed_submission_returns_continuous_timeline() {
        let cfg = tiny_cfg();
        let kv = KvCacheOpts { page_rows: 4, ..Default::default() };
        let handle = start_continuous(
            move || Ok(CachedNativeBackend::dense(cfg, init_params(&cfg, 0), kv)),
            ContinuousOpts { prefill_chunk: 4, ..Default::default() },
        );
        let (rx, trx) =
            handle.submit_timed(Request::Generate { prompt: b"the kama ".to_vec(), max_new: 4 });
        match rx.recv().unwrap() {
            Response::Generated { text } => assert_eq!(text.len(), 4),
            other => panic!("unexpected {other:?}"),
        }
        let t = trx.recv().unwrap();
        assert_eq!(t.count(Mark::Finish), 1);
        assert_eq!(t.count(Mark::Admit), 1);
        assert!(t.count(Mark::DecodeStep) >= 1);
        let b = t.breakdown();
        assert_eq!(b.queue_ns + b.prefill_ns + b.decode_ns, b.total_ns);

        // an admission-refused request still answers the timeline channel
        let (rx, trx) =
            handle.submit_timed(Request::Generate { prompt: vec![b'x'; 30], max_new: 10 });
        match rx.recv().unwrap() {
            Response::Rejected { .. } => {}
            other => panic!("expected rejection, got {other:?}"),
        }
        let t = trx.recv().unwrap();
        assert_eq!(t.count(Mark::Admit), 0, "never admitted");
        assert_eq!(t.count(Mark::Finish), 1);

        let metrics = handle.shutdown();
        assert!(!metrics.timelines.is_empty(), "shutdown metrics retain timelines");
    }

    #[test]
    fn timed_submission_returns_lockstep_timeline() {
        let handle = start(tiny_backend, ServerOpts::default());
        let (rx, trx) =
            handle.submit_timed(Request::Generate { prompt: b"abc".to_vec(), max_new: 2 });
        match rx.recv().unwrap() {
            Response::Generated { text } => assert_eq!(text.len(), 2),
            other => panic!("unexpected {other:?}"),
        }
        let t = trx.recv().unwrap();
        assert_eq!(t.count(Mark::Admit), 1);
        assert_eq!(t.count(Mark::Finish), 1);
        let metrics = handle.shutdown();
        assert_eq!(metrics.timelines.len(), 1);
    }

    #[test]
    fn sharded_backends_match_streaming_bitwise() {
        // the sharded executor behind both lockstep backends must produce
        // byte-identical generations and logprobs to the single-engine
        // streaming path — tensor parallelism is a pure speedup
        let cfg = tiny_cfg();
        let store = init_params(&cfg, 0);
        let mut rng = Rng::new(5);
        let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
        let mut cap = CalibCapture::new(16, 0);
        native_fwd::forward(&cfg, &store, &toks, 2, Some(&mut cap)).unwrap();
        let calib = cap.into_calib_set();
        let mut opts = PipelineOpts::default();
        opts.target_bits = 3.0;
        opts.bit_allocation = false;
        let (qm, _) =
            quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).unwrap();

        let req = [
            Request::Generate { prompt: b"the kama ".to_vec(), max_new: 6 },
            Request::Score { prompt: b"the ".to_vec(), continuation: b"ka".to_vec() },
        ];
        let mut m = ServerMetrics::default();

        let mut streamed = StreamingNativeBackend {
            cfg,
            store: store.clone(),
            qm: qm.clone(),
            engine: StreamingMatmul::new(8, 2),
            stats: DecodeStats::default(),
        };
        let want = run_batch(&mut streamed, &req, &mut m);

        let sopts = ShardOpts { shards: 2, panel_rows: 8, threads_per_shard: 1 };
        let mut sharded =
            ShardedNativeBackend::new(cfg, store.clone(), qm.clone(), sopts);
        let got = run_batch(&mut sharded, &req, &mut m);

        let kv = KvCacheOpts { page_rows: 8, ..Default::default() };
        let mut cached =
            CachedNativeBackend::sharded(cfg, store, qm, sopts, kv);
        let got_cached = run_batch(&mut cached, &req, &mut m);

        for other in [&got, &got_cached] {
            for (x, y) in want.iter().zip(other.iter()) {
                match (x, y) {
                    (Response::Generated { text: tx }, Response::Generated { text: ty }) => {
                        assert_eq!(tx, ty, "sharded generation diverged")
                    }
                    (Response::Scored { logprob: lx }, Response::Scored { logprob: ly }) => {
                        assert!((lx - ly).abs() < 1e-12, "{lx} vs {ly}")
                    }
                    pair => panic!("mismatched kinds {pair:?}"),
                }
            }
        }
        let per = sharded.shard_stats().expect("sharded backend reports shard stats");
        assert_eq!(per.len(), 2);
        assert!(per.iter().any(|p| p.jobs > 0));
        assert!(cached.shard_stats().is_some());
        assert!(cached.decode_stats().is_some());
    }

    #[test]
    fn streaming_backend_matches_dense_generation() {
        // compressed-weights serving must generate the same bytes as dense
        // serving over the dequantized weights of the same container
        let cfg = tiny_cfg();
        let store = init_params(&cfg, 0);
        let mut rng = Rng::new(5);
        let toks: Vec<i32> = (0..2 * cfg.seq_len).map(|_| rng.below(256) as i32).collect();
        let mut cap = CalibCapture::new(16, 0);
        native_fwd::forward(&cfg, &store, &toks, 2, Some(&mut cap)).unwrap();
        let calib = cap.into_calib_set();
        let mut opts = PipelineOpts::default();
        opts.target_bits = 3.0;
        opts.bit_allocation = false;
        let (qm, _) =
            quantize_model(&cfg.param_specs(), &store, &calib, &RtnQuantizer, &opts).unwrap();
        let dq = crate::glvq::pipeline::dequantized_store(&qm, &store);

        let mut dense = NativeBackend { cfg, store: dq };
        let mut streamed = StreamingNativeBackend {
            cfg,
            store,
            qm,
            engine: StreamingMatmul::new(8, 2),
            stats: DecodeStats::default(),
        };
        let req = [Request::Generate { prompt: b"the kama ".to_vec(), max_new: 6 }];
        let mut m = ServerMetrics::default();
        let a = run_batch(&mut dense, &req, &mut m).remove(0);
        let b = run_batch(&mut streamed, &req, &mut m).remove(0);
        match (a, b) {
            (Response::Generated { text: ta }, Response::Generated { text: tb }) => {
                assert_eq!(ta, tb, "streamed generation diverged from dense")
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
