//! Streaming on-the-fly decoding — the paper's §3.4 runtime contribution:
//! "materialise just a handful of sub-blocks, apply ŵ = F⁻¹(G z) and
//! release the data immediately after use", bounding peak memory at
//! activations + one sub-block panel instead of the whole dequantized layer.
//!
//! [`StreamingMatmul`] is the serving engine: Y = X · Wᵀ_q for an
//! activation batch X (B × n_in) against a quantized tensor storing Wᵀ
//! (m × n_in). Each group-panel is decoded **exactly once per batch** —
//! rANS chunk decode, Babai grid expansion and companding inversion are
//! amortized across all B activation rows instead of paid per vector — and
//! row-panel work items are distributed over
//! [`crate::coordinator::scheduler::parallel_map`] worker threads, each
//! with its own scratch buffers and [`DecodeStats`], merged after the
//! join. Output is bit-identical for every batch size and thread count.
//!
//! The decode core is exposed in panel granularity for the
//! tensor-parallel shard executor ([`crate::shard`]):
//! [`StreamingMatmul::panel_slabs`] decodes any subset of a tensor's
//! groups into per-panel partial-product slabs, and [`merge_slabs`] folds
//! slabs into the output in the one canonical (group, panel) order — the
//! same order `matmul` itself uses — so any partition of the group set
//! across shard workers reassembles to the **bit-identical** result.
//! Single-vector decode is just the batch-1 case of `matmul` (the old
//! `StreamingMatvec` wrapper is gone; the Table-4 micro benches drive the
//! shared engine with a 1-row batch).
//!
//! [`DecodeStats`] tracks exact bytes-touched so Table 4's MEM BW column
//! can be reproduced as a bytes-moved model, plus the peak decoded
//! working set backing the paper's >10× peak-memory claim.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::compand::MuLaw;
use crate::coordinator::scheduler::parallel_map;
use crate::kernels::fused::fused_panel_slab;
use crate::kernels::{self, lut, ExecMode, GroupTables, KernelScratch};
use crate::linalg::{Mat, MatView};
use crate::quant::format::QuantizedTensor;
use crate::quant::pack::code_range;
use crate::quant::traits::{hadamard_inverse, sign_vector, QuantizedGroup, SideInfo};

/// Counters for the bytes-moved model (Table 4 MEM BW).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DecodeStats {
    /// code payload bytes read — the *true stored* bytes: bit-granular for
    /// fixed-width payloads, chunk-granular (stream + states + escapes +
    /// frequency table) for entropy-coded payloads
    pub code_bytes: usize,
    /// side-info bytes read (FP16-equivalent accounting)
    pub side_bytes: usize,
    /// activation bytes read + written
    pub act_bytes: usize,
    /// decoded weights produced (elements) — never persisted
    pub weights_decoded: usize,
    /// multiply-accumulate count
    pub macs: usize,
    /// largest decode buffer materialized at any point (elements): the
    /// peak decoded working set per worker — panel-sized for streaming
    /// side-info families, whole-group for lookup/stateful fallbacks
    pub peak_decoded: usize,
}

impl DecodeStats {
    pub fn total_bytes(&self) -> usize {
        self.code_bytes + self.side_bytes + self.act_bytes
    }

    /// Fold another worker's counters into this one (sums; `peak_decoded`
    /// takes the max). Merging per-thread stats in any order yields exactly
    /// the single-thread totals — tested.
    pub fn merge(&mut self, other: &DecodeStats) {
        self.code_bytes += other.code_bytes;
        self.side_bytes += other.side_bytes;
        self.act_bytes += other.act_bytes;
        self.weights_decoded += other.weights_decoded;
        self.macs += other.macs;
        self.peak_decoded = self.peak_decoded.max(other.peak_decoded);
    }
}

/// One unit of parallel work: a row-panel of one group (or, for
/// non-streaming side-info families, the whole group).
#[derive(Clone, Copy)]
struct PanelItem {
    /// index into `qt.groups`
    gi: usize,
    /// first row of this panel within the group
    r: usize,
    /// rows in this panel
    rows: usize,
}

/// One decoded panel's partial product over a batch:
/// `data[b·rows + i] = Σ_c ŵ[r+i][c] · x[b][c0 + c]` for the panel's
/// group. Produced by [`StreamingMatmul::panel_slabs`], consumed by
/// [`merge_slabs`] — the unit of work the shard executor ships between
/// workers and the coordinator.
#[derive(Clone, Debug)]
pub struct PanelSlab {
    /// index into `qt.groups`
    pub gi: usize,
    /// first row of this panel within its group
    pub r: usize,
    /// rows in this panel
    pub rows: usize,
    /// (batch × rows) partial products, b-major
    pub data: Vec<f32>,
}

/// Expand the per-group decode acceleration tables for the listed groups
/// of `qt`: the rANS symbol table for every entropy-coded group (`None`
/// elsewhere). The returned vector is full-length (`qt.groups.len()`),
/// indexable by group index, so a shard worker can build tables for only
/// the groups it owns, once, and reuse them across every batch. Fused
/// code→vector tables attach separately — [`attach_luts`] for persistent
/// workers, the engine's warm cache for everyone else — because they are
/// worth building only for a payload that will be decoded repeatedly.
pub fn kernel_tables(qt: &QuantizedTensor, groups: &[usize]) -> Vec<GroupTables> {
    let _sp = crate::span!("rans_tables");
    let mut tables: Vec<GroupTables> =
        (0..qt.groups.len()).map(|_| GroupTables::default()).collect();
    for &gi in groups {
        if let crate::quant::traits::CodePayload::Rans(rc) = &qt.groups[gi].2.codes {
            tables[gi].rans = Some(rc.hist.decode_table());
        }
    }
    tables
}

/// Build and attach the fused kernel's code→vector tables
/// ([`lut::LutTable`]) for every eligible listed group, in place. For
/// callers that own long-lived [`GroupTables`] (shard workers): call once
/// the tensor is known to be hot. Honors the `GLVQ_LUT=0` kill switch;
/// groups that already carry a table are left untouched.
pub fn attach_luts(qt: &QuantizedTensor, groups: &[usize], tables: &mut [GroupTables]) {
    if !kernels::lut_enabled() {
        return;
    }
    for &gi in groups {
        let g = &qt.groups[gi].2;
        let bits = g.codes.bits();
        let Some(dim) = lut::lut_block_dim(&g.side, bits) else { continue };
        if g.cols % dim != 0 || tables[gi].lut.is_some() {
            continue;
        }
        if let Some(t) = lut::LutTable::build(&g.side, bits) {
            tables[gi].lut = Some(Arc::new(t));
        }
    }
}

/// Fold panel slabs into `y` (`y` pre-zeroed by the caller). Slabs must
/// arrive in the canonical (group index, panel row) ascending order —
/// the order [`StreamingMatmul::matmul`] itself accumulates in — which
/// makes the float result identical no matter how the slabs were
/// produced: one engine, many threads, or many shard workers.
pub fn merge_slabs(qt: &QuantizedTensor, slabs: &[PanelSlab], y: &mut Mat) {
    let batch = y.rows;
    merge_slabs_into(qt, slabs, batch, &mut y.data);
}

/// [`merge_slabs`] against a borrowed output buffer (`batch × qt.rows`,
/// b-major, pre-zeroed) — the allocation-free core the batch-1
/// [`StreamingMatmul::matvec_into`] hot path folds into directly.
pub fn merge_slabs_into(qt: &QuantizedTensor, slabs: &[PanelSlab], batch: usize, out: &mut [f32]) {
    let _sp = crate::span!("merge_slabs");
    let m = qt.rows;
    debug_assert_eq!(out.len(), batch * m);
    debug_assert!(
        slabs.windows(2).all(|w| (w[0].gi, w[0].r) < (w[1].gi, w[1].r)),
        "slabs not in canonical (group, panel) order"
    );
    for s in slabs {
        let r0 = qt.groups[s.gi].0;
        debug_assert_eq!(s.data.len(), batch * s.rows);
        for b in 0..batch {
            let dst = &mut out[b * m + r0 + s.r..b * m + r0 + s.r + s.rows];
            let src = &s.data[b * s.rows..(b + 1) * s.rows];
            for (d, v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }
    }
}

/// One engine's warm cache of fused code→vector tables, keyed by
/// (tensor name, group index) and fingerprint-checked against the
/// group's actual side info so a different tensor reusing a name can
/// never be served stale entries. A table is built only after
/// [`kernels::LUT_WARM_CALLS`] decodes of the same group through this
/// engine — one-shot callers never pay a build — and total resident
/// bytes are capped by [`kernels::LUT_CACHE_BUDGET_BYTES`].
#[derive(Default)]
struct LutCache {
    map: HashMap<(String, usize), LutSlot>,
    bytes: usize,
}

struct LutSlot {
    fp: u64,
    calls: usize,
    table: Option<Arc<lut::LutTable>>,
}

/// Batched multi-threaded streaming decode-matmul engine.
///
/// Holds one scratch slab per worker thread behind a mutex pool; `matmul`
/// is `&self`, so a single engine can be shared across layers and calls.
pub struct StreamingMatmul {
    /// rows per streamed panel (the "handful of sub-blocks")
    pub panel_rows: usize,
    /// worker threads row-panel items are spread over
    pub threads: usize,
    /// execution mode: fused decode-GEMM vs classic decode-then-FMA slab
    /// path (resolved from [`kernels::resolve_mode`] at construction,
    /// overridable via [`StreamingMatmul::with_mode`]). Both modes are
    /// bit-identical in scalar execution — tested.
    mode: ExecMode,
    /// SIMD lane reduction inside the fused dot product; only ever true
    /// when the `simd` cargo feature is compiled in AND the runtime
    /// opted in (GLVQ_SIMD=1 / `serve --fused` / `with_simd`)
    simd: bool,
    scratch: Vec<Mutex<KernelScratch>>,
    lut_cache: Mutex<LutCache>,
}

impl StreamingMatmul {
    pub fn new(panel_rows: usize, threads: usize) -> StreamingMatmul {
        let threads = threads.max(1);
        StreamingMatmul {
            panel_rows: panel_rows.max(1),
            threads,
            mode: kernels::resolve_mode(),
            simd: kernels::resolve_simd(),
            scratch: (0..threads).map(|_| Mutex::new(KernelScratch::default())).collect(),
            lut_cache: Mutex::new(LutCache::default()),
        }
    }

    /// Builder: pin the execution mode, overriding the process-level
    /// resolution. `ExecMode::Slab` also disables the LUT warm cache.
    pub fn with_mode(mut self, mode: ExecMode) -> StreamingMatmul {
        self.mode = mode;
        self
    }

    /// Builder: opt this engine in/out of SIMD lane reduction. A no-op
    /// (stays scalar) when the `simd` cargo feature is not compiled in.
    pub fn with_simd(mut self, on: bool) -> StreamingMatmul {
        self.simd = on && cfg!(feature = "simd");
        self
    }

    /// The execution mode this engine resolved to.
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Effective panel rows for one group: `panel_rows`, except rANS
    /// payloads whose chunk rows align — there the panel snaps to whole
    /// chunks so every chunk is decoded (and charged) exactly once per
    /// batch. This is also the working-set bound `peak_panel_elems`
    /// reports: chunk-granular decode cannot go below one chunk.
    fn effective_panel_rows(&self, g: &QuantizedGroup) -> usize {
        let (m, n) = (g.rows, g.cols.max(1));
        match &g.codes {
            crate::quant::traits::CodePayload::Rans(rc) if rc.chunk_len % n == 0 => {
                let chunk_rows = (rc.chunk_len / n).max(1);
                if chunk_rows >= self.panel_rows {
                    chunk_rows.min(m)
                } else {
                    ((self.panel_rows / chunk_rows) * chunk_rows).min(m)
                }
            }
            _ => self.panel_rows.min(m),
        }
    }

    /// Y = decode(qt) applied to the batch: `y[b] += decode(qt) · x[b]` for
    /// every batch row b. `x` is (B × n_in), `y` is (B × m); `y` is
    /// overwritten. Each group-panel is decoded once for the whole batch;
    /// panels are processed on `self.threads` workers with per-thread
    /// scratch and stats merged into `stats` after the join. The result is
    /// bit-identical across batch sizes and thread counts.
    pub fn matmul(&self, qt: &QuantizedTensor, x: &Mat, y: &mut Mat, stats: &mut DecodeStats) {
        let _sp = crate::span!("decode_matmul");
        let batch = x.rows;
        assert_eq!((y.rows, y.cols), (batch, qt.rows), "{}: bad output shape", qt.name);
        y.data.fill(0.0);
        stats.act_bytes += (x.data.len() + y.data.len()) * 4;

        // expand each group's rANS decode table once per batch (not per
        // panel, not per vector) and share it across workers; attach any
        // warm fused LUTs from this engine's cache
        let all: Vec<usize> = (0..qt.groups.len()).collect();
        let mut tables = kernel_tables(qt, &all);
        self.attach_cached_luts(qt, &all, &mut tables);
        let slabs = self.panel_slabs(qt, &all, &tables, MatView::of(x), stats);
        // slabs land in canonical item order regardless of which worker
        // ran them, so accumulation order (and hence the float result) is
        // deterministic
        merge_slabs(qt, &slabs, y);
    }

    /// Attach fused code→vector tables for eligible groups from this
    /// engine's warm cache, building a table only once a group has been
    /// decoded [`kernels::LUT_WARM_CALLS`] times through this engine and
    /// the cache budget allows it. Slab mode and `GLVQ_LUT=0` skip
    /// entirely. Tables are fingerprint-verified against the group's side
    /// info, so a different tensor reusing a cached name rebuilds instead
    /// of serving stale entries.
    fn attach_cached_luts(
        &self,
        qt: &QuantizedTensor,
        groups: &[usize],
        tables: &mut [GroupTables],
    ) {
        if self.mode == ExecMode::Slab || !kernels::lut_enabled() {
            return;
        }
        let mut guard = self.lut_cache.lock().expect("lut cache mutex poisoned");
        let LutCache { map, bytes } = &mut *guard;
        for &gi in groups {
            let g = &qt.groups[gi].2;
            let bits = g.codes.bits();
            let Some(dim) = lut::lut_block_dim(&g.side, bits) else { continue };
            if g.cols % dim != 0 {
                continue;
            }
            let fp = lut::group_fingerprint(g);
            let slot = map
                .entry((qt.name.clone(), gi))
                .or_insert(LutSlot { fp, calls: 0, table: None });
            if slot.fp != fp {
                // same (tensor name, group index), different content:
                // drop the stale table and restart the warm counter
                if let Some(t) = slot.table.take() {
                    *bytes = bytes.saturating_sub(t.bytes());
                }
                slot.fp = fp;
                slot.calls = 0;
            }
            slot.calls += 1;
            if slot.table.is_none() && slot.calls >= kernels::LUT_WARM_CALLS {
                let est = lut::lut_bytes_estimate(&g.side, bits).unwrap_or(usize::MAX);
                if bytes.saturating_add(est) <= kernels::LUT_CACHE_BUDGET_BYTES {
                    if let Some(t) = lut::LutTable::build(&g.side, bits) {
                        *bytes += t.bytes();
                        slot.table = Some(Arc::new(t));
                    }
                }
            }
            if let Some(t) = &slot.table {
                tables[gi].lut = Some(Arc::clone(t));
            }
        }
    }

    /// Decode-matmul a **subset** of `qt`'s groups against the batch,
    /// returning one partial-product slab per row-panel in canonical
    /// (group index, panel row) order. `tables` is the full-length
    /// [`GroupTables`] vector from [`kernel_tables`] (the caller owns it
    /// so shard workers can build their groups' tables once and reuse
    /// them across batches; [`attach_luts`] upgrades hot groups). Per-item
    /// [`DecodeStats`] are merged into `stats`; the activation traffic
    /// (`act_bytes`) is *not* charged here — the caller that owns x/y
    /// charges it once per call, so stats stay identical however the
    /// groups are partitioned.
    ///
    /// This is the shard executor's work unit: `matmul` is exactly
    /// `panel_slabs` over all groups followed by [`merge_slabs`].
    pub fn panel_slabs(
        &self,
        qt: &QuantizedTensor,
        groups: &[usize],
        tables: &[GroupTables],
        x: MatView<'_>,
        stats: &mut DecodeStats,
    ) -> Vec<PanelSlab> {
        assert_eq!(x.cols, qt.cols, "{}: x cols {} != n_in {}", qt.name, x.cols, qt.cols);
        assert_eq!(tables.len(), qt.groups.len(), "{}: bad table vector", qt.name);
        // one work item per row-panel (whole group for non-streaming
        // side-info families); the item list is independent of the thread
        // count, so per-item stats sum to the same totals either way
        let mut items: Vec<PanelItem> = Vec::new();
        for &gi in groups {
            let g = &qt.groups[gi].2;
            if !supports_streaming(&g.side) {
                items.push(PanelItem { gi, r: 0, rows: g.rows });
                continue;
            }
            let pr = self.effective_panel_rows(g);
            let mut r = 0usize;
            while r < g.rows {
                let rows = pr.min(g.rows - r);
                items.push(PanelItem { gi, r, rows });
                r += rows;
            }
        }

        let slabs = parallel_map(self.threads, &items, |worker, _idx, item| {
            // one span per row-panel on the worker's own thread track;
            // inert (a single atomic load) when tracing is off
            let _sp = crate::span!("panel_decode");
            let (_, c0, g) = &qt.groups[item.gi];
            let mut scratch = self.acquire_scratch(worker);
            let mut st = DecodeStats::default();
            let gt = &tables[item.gi];
            let fused = self.mode != ExecMode::Slab && supports_streaming(&g.side);
            let slab = if fused {
                match fused_panel_slab(
                    g,
                    *c0,
                    item.r,
                    item.rows,
                    gt,
                    x,
                    &mut scratch,
                    &mut st,
                    self.simd,
                ) {
                    Ok(s) => s,
                    Err(_) => {
                        // misrouted family: discard the fused attempt's
                        // counters and redo through the slab path so the
                        // stats match slab-mode execution exactly
                        st = DecodeStats::default();
                        panel_slab(g, *c0, item, gt, x, &mut scratch, &mut st)
                    }
                }
            } else {
                panel_slab(g, *c0, item, gt, x, &mut scratch, &mut st)
            };
            // side info is charged once per group per batch: on its first panel
            if item.r == 0 {
                st.side_bytes += g.side_bytes();
            }
            (slab, st)
        })
        .unwrap_or_else(|(i, msg)| panic!("streaming matmul worker panicked on item {i}: {msg}"));

        items
            .iter()
            .zip(slabs)
            .map(|(item, (data, st))| {
                stats.merge(&st);
                PanelSlab { gi: item.gi, r: item.r, rows: item.rows, data }
            })
            .collect()
    }

    /// Single-vector convenience: `y = decode(qt) · x` as the batch-1
    /// case of [`StreamingMatmul::matmul`] — same decode core, same
    /// stats accounting (what the deleted `StreamingMatvec` wrapper
    /// used to provide). Used by the Table-4 micro benches and the
    /// roundtrip tests.
    pub fn matvec(&self, qt: &QuantizedTensor, x: &[f32], stats: &mut DecodeStats) -> Vec<f32> {
        let mut y = vec![0.0f32; qt.rows];
        self.matvec_into(qt, x, &mut y, stats);
        y
    }

    /// Allocation-free single-vector decode-matmul: `y = decode(qt) · x`
    /// against caller-owned buffers. `x` is borrowed (no clone into a
    /// batch matrix) and `y` (len `qt.rows`) is overwritten — the batch-1
    /// token-decode hot path reuses one output buffer across steps.
    /// Bit-identical to `matmul` with a 1-row batch.
    pub fn matvec_into(
        &self,
        qt: &QuantizedTensor,
        x: &[f32],
        y: &mut [f32],
        stats: &mut DecodeStats,
    ) {
        let _sp = crate::span!("decode_matmul");
        assert_eq!(y.len(), qt.rows, "{}: bad output length", qt.name);
        y.fill(0.0);
        stats.act_bytes += (x.len() + y.len()) * 4;
        let all: Vec<usize> = (0..qt.groups.len()).collect();
        let mut tables = kernel_tables(qt, &all);
        self.attach_cached_luts(qt, &all, &mut tables);
        let slabs = self.panel_slabs(qt, &all, &tables, MatView::from_slice(1, x.len(), x), stats);
        merge_slabs_into(qt, &slabs, 1, y);
    }

    /// Grab this worker's own scratch slab. Pool size == threads and
    /// worker ids from [`parallel_map`] are stable in `0..threads`, so
    /// the lock is always uncontended — no try-lock scan over slots other
    /// workers hold.
    fn acquire_scratch(&self, worker: usize) -> std::sync::MutexGuard<'_, KernelScratch> {
        self.scratch[worker % self.scratch.len()]
            .lock()
            .expect("scratch mutex poisoned")
    }

    /// Peak decoded-weights working set in elements — the quantity the
    /// paper claims drops >10× vs layer-at-once decode. Streaming groups
    /// are bounded by one panel (rANS panels snap to whole chunks, so the
    /// bound reflects the buffers actually allocated); lookup/stateful
    /// families that cannot stream count their full group.
    pub fn peak_panel_elems(&self, qt: &QuantizedTensor) -> usize {
        qt.groups
            .iter()
            .map(|(_, _, g)| {
                if supports_streaming(&g.side) {
                    self.effective_panel_rows(g) * g.cols
                } else {
                    g.rows * g.cols
                }
            })
            .max()
            .unwrap_or(0)
    }
}

/// Decode one panel of `g` and return its partial product slab
/// (batch × rows, b-major): `slab[b][i] = Σ_c panel[i][c] · x[b][c0 + c]`.
fn panel_slab(
    g: &QuantizedGroup,
    c0: usize,
    item: &PanelItem,
    tables: &GroupTables,
    x: MatView<'_>,
    scratch: &mut KernelScratch,
    stats: &mut DecodeStats,
) -> Vec<f32> {
    let (n, batch) = (g.cols, x.rows);
    let rows = item.rows;
    let mut slab = vec![0.0f32; batch * rows];

    if !supports_streaming(&g.side) {
        // lookup/stateful methods (codebook, trellis, binary) cannot decode
        // from an arbitrary offset: dequantize the whole group — exactly
        // the operational cost the paper charges AQLM-style methods in
        // Table 4.
        debug_assert_eq!((item.r, rows), (0, g.rows));
        let dense = g.dequantize();
        stats.code_bytes += g.codes.payload_bytes();
        stats.weights_decoded += rows * n;
        stats.peak_decoded = stats.peak_decoded.max(rows * n);
        for b in 0..batch {
            let xr = &x.row(b)[c0..c0 + n];
            for i in 0..rows {
                let row = dense.row(i);
                let mut acc = 0.0f32;
                for (a, v) in row.iter().zip(xr.iter()) {
                    acc += a * v;
                }
                slab[b * rows + i] = acc;
            }
        }
        stats.macs += batch * rows * n;
        return slab;
    }

    let count = rows * n;
    scratch.codes_buf.resize(count, 0);
    scratch.panel.resize(count, 0.0);
    match (&g.codes, tables.rans.as_ref()) {
        (crate::quant::traits::CodePayload::Rans(rc), Some(t)) => rc.decode_range_with(
            item.r * n,
            &mut scratch.codes_buf[..count],
            t,
            &mut scratch.rans_scratch,
        ),
        _ => g.codes.unpack_range_into(item.r * n, &mut scratch.codes_buf[..count]),
    }
    stats.code_bytes += g.codes.range_payload_bytes(item.r * n, count);
    if let SideInfo::Lattice { d, g: gmat, mu, scale } = &g.side {
        // §Perf fast path: blocked GEMM (B×d)@(d×d) + vectorized μ-law
        // expand instead of per-block scalar loops. The accumulation order
        // matches the scalar `dequantize` exactly, so the decoded panel is
        // bit-identical to the dense oracle.
        let d = *d;
        scratch.zf.resize(count, 0.0);
        for (zf, &c) in scratch.zf.iter_mut().zip(&scratch.codes_buf[..count]) {
            *zf = c as f32 + 0.5;
        }
        let zb = Mat::from_vec(count / d, d, scratch.zf[..count].to_vec());
        let gm = Mat::from_vec(d, d, gmat.clone());
        let mut vb = Mat::zeros(count / d, d);
        crate::linalg::matrix::matmul_into(&zb, &gm.transpose(), &mut vb);
        let comp = MuLaw::new(*mu);
        comp.inverse_slice(&mut vb.data);
        for (o, v) in scratch.panel[..count].iter_mut().zip(&vb.data) {
            *o = scale * v;
        }
    } else if decode_codes(
        &g.side,
        g.codes.bits(),
        &scratch.codes_buf[..count],
        &mut scratch.panel[..count],
    )
    .is_err()
    {
        // A family the streaming decoder cannot serve was misrouted onto
        // the panel path (`supports_streaming` normally sends it to the
        // whole-group branch above). Degrade to a whole-group decode of
        // this panel's rows instead of aborting the serving thread.
        let dense = g.dequantize();
        let lo = item.r * n;
        scratch.panel[..count].copy_from_slice(&dense.data[lo..lo + count]);
    }
    stats.weights_decoded += count;
    stats.peak_decoded = stats.peak_decoded.max(count);

    // slab[b] = panel · x[b], decoded weights reused across the whole batch
    for b in 0..batch {
        let xr = &x.row(b)[c0..c0 + n];
        for i in 0..rows {
            let row = &scratch.panel[i * n..(i + 1) * n];
            let mut acc = 0.0f32;
            for (a, v) in row.iter().zip(xr.iter()) {
                acc += a * v;
            }
            slab[b * rows + i] = acc;
        }
    }
    stats.macs += batch * count;
    slab
}

/// Structured error for a decode request the streaming path cannot
/// serve: the group's side-info family needs whole-group context (e.g.
/// per-row scales, trellis state from position 0) that a mid-stream
/// panel does not carry. Callers degrade to `QuantizedGroup::dequantize`
/// instead of aborting.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnstreamableDecode {
    /// side-info family name of the misrouted group
    pub family: &'static str,
}

impl std::fmt::Display for UnstreamableDecode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} decode is not on the streaming path (needs whole-group dequantize)",
            self.family
        )
    }
}

impl std::error::Error for UnstreamableDecode {}

/// Decode a run of codes into weights for any streaming side-info family.
/// The per-family math matches `QuantizedGroup::dequantize` exactly
/// (tested). `codes` holds whole rows, row-major, row length divisible by
/// d/dim. A family that cannot decode from an arbitrary offset returns
/// [`UnstreamableDecode`] so the caller can fall back to a whole-group
/// decode.
pub(crate) fn decode_codes(
    side: &SideInfo,
    bits: u8,
    codes: &[i32],
    out: &mut [f32],
) -> std::result::Result<(), UnstreamableDecode> {
    match side {
        SideInfo::Uniform { scale, zero } => {
            for (o, &c) in out.iter_mut().zip(codes) {
                *o = c as f32 * scale + zero;
            }
        }
        SideInfo::Lattice { d, g, mu, scale } => {
            let d = *d;
            let comp = MuLaw::new(*mu);
            let blocks = codes.len() / d;
            for b in 0..blocks {
                let z = &codes[b * d..(b + 1) * d];
                // half-integer grid: ŵ = scale · F⁻¹(G (z + ½))
                for i in 0..d {
                    let mut acc = 0.0f32;
                    let row = &g[i * d..(i + 1) * d];
                    for (j, &zj) in z.iter().enumerate() {
                        acc += row[j] * (zj as f32 + 0.5);
                    }
                    out[b * d + i] = scale * comp.inverse(acc);
                }
            }
        }
        SideInfo::RotatedLattice { d, scale, sign_seed } => {
            let d = *d;
            let signs = sign_vector(*sign_seed, d);
            let blocks = codes.len() / d;
            let mut y = vec![0.0f32; d];
            for b in 0..blocks {
                for i in 0..d {
                    y[i] = codes[b * d + i] as f32 * 0.5;
                }
                let w = hadamard_inverse(&y);
                for i in 0..d {
                    out[b * d + i] = w[i] * signs[i] * scale;
                }
            }
        }
        SideInfo::Codebook { dim, centers } => {
            let dim = *dim;
            let lo = code_range(bits).0;
            // NB: for codebook methods `codes` are block indices (one per
            // dim-length block); callers pass rows in block units.
            for (b, &c) in codes.iter().enumerate() {
                let idx = (c - lo) as usize;
                out[b * dim..(b + 1) * dim].copy_from_slice(&centers[idx * dim..(idx + 1) * dim]);
            }
        }
        SideInfo::Trellis { levels, states } => {
            let per = levels.len() / 4;
            let lo = code_range(bits).0;
            let smask = states - 1;
            let mut state = 0usize;
            for (o, &c) in out.iter_mut().zip(codes) {
                let u = ((c - lo) as usize) & 1;
                let j = ((c - lo) as usize) >> 1;
                let subset = ((state & 1) << 1) | u;
                *o = levels[subset * per + j.min(per - 1)];
                state = ((state << 1) | u) & smask;
            }
        }
        SideInfo::Binary { .. } => {
            // binary decode needs row indices for per-row scales; handled by
            // dequantize() — supports_streaming() routes binary to the dense
            // fallback, so reaching here means a misrouted op. Degrade via a
            // structured error instead of aborting the serving thread.
            return Err(UnstreamableDecode { family: "binary" });
        }
    }
    Ok(())
}

/// Streaming decoder caveats per method (documented behaviour):
/// - Lattice/Uniform/RotatedLattice stream exactly.
/// - Codebook streams in block units (the caller must align panels).
/// - Trellis decode is stateful from position 0, so `unpack_range_into`
///   cannot start mid-stream; the engine therefore decodes whole groups
///   for TCQ/binary/codebook (see `supports_streaming`).
pub fn supports_streaming(side: &SideInfo) -> bool {
    !matches!(side, SideInfo::Trellis { .. } | SideInfo::Binary { .. } | SideInfo::Codebook { .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::config::GlvqConfig;
    use crate::glvq::optimizer::GlvqGroupQuantizer;
    use crate::linalg::Mat;
    use crate::quant::traits::GroupQuantizer;
    use crate::util::rng::Rng;

    fn quantized_tensor(method: &str, seed: u64) -> (Mat, QuantizedTensor) {
        let mut rng = Rng::new(seed);
        let wt = Mat::random_normal(32, 64, 0.05, &mut rng); // (m × n)
        let x = Mat::random_normal(32, 32, 1.0, &mut rng);
        let mut groups = Vec::new();
        for gi in 0..2 {
            let panel = wt.slice(0, 32, gi * 32, (gi + 1) * 32);
            let qg = match method {
                "glvq" => {
                    let mut cfg = GlvqConfig::default();
                    cfg.lattice_dim = 8;
                    cfg.group_size = 32;
                    cfg.iters = 4;
                    GlvqGroupQuantizer::new(cfg).quantize(&panel, &x, 2)
                }
                _ => RtnQuantizer.quantize(&panel, &x, 2),
            };
            groups.push((0usize, gi * 32, qg));
        }
        (wt, QuantizedTensor { name: "t".into(), rows: 32, cols: 64, groups })
    }

    /// Dense dequantize + matmul oracle with the engine's accumulation
    /// structure (per-group sequential dots, groups merged in order) — the
    /// reference the streaming path must match *bit-exactly*.
    fn oracle_matmul(qt: &QuantizedTensor, x: &Mat) -> Mat {
        let mut y = Mat::zeros(x.rows, qt.rows);
        for (r0, c0, g) in &qt.groups {
            let dense = g.dequantize();
            for b in 0..x.rows {
                let xr = &x.row(b)[*c0..*c0 + g.cols];
                for i in 0..g.rows {
                    let row = dense.row(i);
                    let mut acc = 0.0f32;
                    for (a, v) in row.iter().zip(xr.iter()) {
                        acc += a * v;
                    }
                    *y.at_mut(b, r0 + i) += acc;
                }
            }
        }
        y
    }

    /// Re-encode every group payload with rANS (`rows_per_chunk` rows per
    /// chunk) — lossless, so all decode paths must agree bit-for-bit.
    fn to_entropy_tensor(qt: &QuantizedTensor, rows_per_chunk: usize) -> QuantizedTensor {
        let mut out = qt.clone();
        for (_, _, g) in &mut out.groups {
            g.codes = g.codes.to_entropy(g.cols * rows_per_chunk.max(1), 4);
        }
        out
    }

    #[test]
    fn streaming_matmul_equals_dense_oracle_bitexact() {
        // fixed + rANS payloads × batch sizes × thread counts × execution
        // modes × a panel size (5) that leaves a ragged 2-row tail on the
        // 32-row groups. The fused mode must be bit-identical to the slab
        // mode and to the dense oracle — the scalar fused kernel's core
        // contract.
        for method in ["rtn", "glvq"] {
            let (_, qt) = quantized_tensor(method, 3);
            for payload in ["fixed", "rans"] {
                let qt = if payload == "rans" { to_entropy_tensor(&qt, 5) } else { qt.clone() };
                for &batch in &[1usize, 3, 16] {
                    let mut rng = Rng::new(4);
                    let x = Mat::random_normal(batch, 64, 1.0, &mut rng);
                    let want = oracle_matmul(&qt, &x);
                    for &threads in &[1usize, 4] {
                        for mode in [ExecMode::Auto, ExecMode::Fused, ExecMode::Slab] {
                            let sm = StreamingMatmul::new(5, threads).with_mode(mode);
                            let mut y = Mat::zeros(batch, 32);
                            let mut stats = DecodeStats::default();
                            sm.matmul(&qt, &x, &mut y, &mut stats);
                            assert_eq!(
                                y.data,
                                want.data,
                                "{method}/{payload} batch={batch} threads={threads} \
                                 mode={} not bit-exact",
                                mode.name()
                            );
                            assert_eq!(stats.macs, batch * 32 * 64);
                            assert!(stats.code_bytes > 0);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn lut_cache_warms_without_changing_bits_or_stats() {
        // repeated matmuls through one engine cross the LUT warm
        // threshold; the post-warm LUT decode must stay bit-identical to
        // the first (pre-warm, direct-decode) call and charge the same
        // DecodeStats. Slab mode is the reference.
        let (_, qt) = quantized_tensor("glvq", 17);
        for payload in ["fixed", "rans"] {
            let qt = if payload == "rans" { to_entropy_tensor(&qt, 8) } else { qt.clone() };
            let mut rng = Rng::new(18);
            let x = Mat::random_normal(4, 64, 1.0, &mut rng);
            let slab = StreamingMatmul::new(8, 2).with_mode(ExecMode::Slab);
            let mut want = Mat::zeros(4, 32);
            let mut s_want = DecodeStats::default();
            slab.matmul(&qt, &x, &mut want, &mut s_want);

            let fused = StreamingMatmul::new(8, 2); // Auto: warms its LUT cache
            for call in 0..(kernels::LUT_WARM_CALLS + 2) {
                let mut y = Mat::zeros(4, 32);
                let mut s = DecodeStats::default();
                fused.matmul(&qt, &x, &mut y, &mut s);
                assert_eq!(y.data, want.data, "{payload}: call {call} drifted from slab mode");
                assert_eq!(s, s_want, "{payload}: call {call} stats drifted from slab mode");
            }
        }
    }

    #[test]
    fn merged_multithread_stats_equal_single_thread() {
        for method in ["rtn", "glvq"] {
            let (_, qt) = quantized_tensor(method, 9);
            let qte = to_entropy_tensor(&qt, 8);
            for t in [&qt, &qte] {
                let mut rng = Rng::new(10);
                let x = Mat::random_normal(7, 64, 1.0, &mut rng);
                let mut y1 = Mat::zeros(7, 32);
                let mut y4 = Mat::zeros(7, 32);
                let mut s1 = DecodeStats::default();
                let mut s4 = DecodeStats::default();
                StreamingMatmul::new(8, 1).matmul(t, &x, &mut y1, &mut s1);
                StreamingMatmul::new(8, 4).matmul(t, &x, &mut y4, &mut s4);
                assert_eq!(s1, s4, "{method}: merged stats drifted across thread counts");
                assert_eq!(y1.data, y4.data);
            }
        }
    }

    #[test]
    fn batch_amortizes_decode_exactly_once() {
        // batch-16 matmul decodes (and charges) each panel once; 16
        // separate batch-1 calls decode it 16 times — same math, 16× the
        // decode traffic. Row b of the batched result equals the b-th
        // batch-1 call bit-exactly.
        let (_, qt) = quantized_tensor("glvq", 6);
        let qte = to_entropy_tensor(&qt, 8);
        let mut rng = Rng::new(12);
        let x = Mat::random_normal(16, 64, 1.0, &mut rng);

        let sm = StreamingMatmul::new(8, 2);
        let mut yb = Mat::zeros(16, 32);
        let mut sb = DecodeStats::default();
        sm.matmul(&qte, &x, &mut yb, &mut sb);

        let mv = StreamingMatmul::new(8, 1);
        let mut sv = DecodeStats::default();
        for b in 0..16 {
            let y = mv.matvec(&qte, x.row(b), &mut sv);
            assert_eq!(y, yb.row(b), "batch row {b} diverged from batch-1 call");
        }
        assert_eq!(sv.code_bytes, 16 * sb.code_bytes, "decode not amortized across batch");
        assert_eq!(sv.weights_decoded, 16 * sb.weights_decoded);
        assert_eq!(sv.macs, sb.macs);
    }

    #[test]
    fn streaming_batch1_equals_dense_dequantize_matvec() {
        for method in ["rtn", "glvq"] {
            let (_, qt) = quantized_tensor(method, 3);
            let mut rng = Rng::new(4);
            let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            let dense = qt.dequantize();
            let want = dense.matvec(&x);
            let sm = StreamingMatmul::new(8, 1);
            let mut stats = DecodeStats::default();
            let y = sm.matvec(&qt, &x, &mut stats);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{method}: {a} vs {b}");
            }
            assert!(stats.code_bytes > 0 && stats.macs == 32 * 64);
        }
    }

    #[test]
    fn streaming_batch1_matches_oracle_on_entropy_payloads() {
        for method in ["rtn", "glvq"] {
            let (_, qt) = quantized_tensor(method, 7);
            let dense = qt.dequantize();
            let mut rng = Rng::new(8);
            let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            let want = dense.matvec(&x);
            // chunking both aligned (8 rows = panel) and misaligned (5 rows)
            for rows_per_chunk in [1usize, 5, 8, 64] {
                let qte = to_entropy_tensor(&qt, rows_per_chunk);
                // lossless re-encode: dequantize is bit-identical
                assert_eq!(qte.dequantize().data, dense.data);
                let sm = StreamingMatmul::new(8, 1);
                let mut stats = DecodeStats::default();
                let y = sm.matvec(&qte, &x, &mut stats);
                for (a, b) in y.iter().zip(&want) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "{method}/chunk{rows_per_chunk}: {a} vs {b}"
                    );
                }
                assert!(stats.code_bytes > 0 && stats.macs == 32 * 64);
            }
        }
    }

    #[test]
    fn entropy_code_bytes_reflect_compressed_payload() {
        // skewed codes → the streamed byte count must track the compressed
        // size, which for near-constant codes is far below fixed-width
        let codes = vec![0i32; 64 * 64];
        let qg = crate::quant::traits::QuantizedGroup {
            method: "rtn",
            bits: 4,
            rows: 64,
            cols: 64,
            codes: crate::quant::pack::PackedCodes::pack(&codes, 4).into(),
            side: SideInfo::Uniform { scale: 0.1, zero: 0.0 },
        };
        let fixed_bytes = qg.codes.payload_bytes();
        let mut qge = qg.clone();
        qge.codes = qge.codes.to_entropy(64 * 8, 4);
        let qt = QuantizedTensor {
            name: "e".into(),
            rows: 64,
            cols: 64,
            groups: vec![(0, 0, qge)],
        };
        let sm = StreamingMatmul::new(8, 1);
        let mut stats = DecodeStats::default();
        let x = vec![1.0f32; 64];
        sm.matvec(&qt, &x, &mut stats);
        assert!(
            stats.code_bytes < fixed_bytes / 4,
            "compressed traffic {} vs fixed {}",
            stats.code_bytes,
            fixed_bytes
        );
        // panels aligned to chunks → every chunk is charged exactly once
        assert_eq!(stats.code_bytes, qt.groups[0].2.codes.payload_bytes());
    }

    #[test]
    fn panel_size_bounds_peak_memory() {
        let (_, qt) = quantized_tensor("rtn", 5);
        let sm = StreamingMatmul::new(4, 1);
        // 4 rows × 32-col group = 128 elems vs full 32×64 = 2048 → 16×
        assert_eq!(sm.peak_panel_elems(&qt), 4 * 32);
        assert!(sm.peak_panel_elems(&qt) * 10 <= qt.rows * qt.cols);
    }

    #[test]
    fn subset_slabs_merge_to_full_matmul_bitexact() {
        // the shard executor's core identity: decoding disjoint group
        // subsets on separate engines and merging the slabs in canonical
        // order reproduces the one-engine matmul bit-for-bit (fixed and
        // rANS payloads), and the summed stats match
        for payload in ["fixed", "rans"] {
            let (_, qt) = quantized_tensor("glvq", 11);
            let qt = if payload == "rans" { to_entropy_tensor(&qt, 5) } else { qt };
            let mut rng = Rng::new(14);
            let x = Mat::random_normal(3, 64, 1.0, &mut rng);

            let mut want = Mat::zeros(3, 32);
            let mut s_full = DecodeStats::default();
            StreamingMatmul::new(5, 2).matmul(&qt, &x, &mut want, &mut s_full);

            // two "shards": one per group, each with its own engine+tables
            let e0 = StreamingMatmul::new(5, 1);
            let e1 = StreamingMatmul::new(5, 1);
            let t0 = kernel_tables(&qt, &[0]);
            let t1 = kernel_tables(&qt, &[1]);
            let mut s0 = DecodeStats::default();
            let mut s1 = DecodeStats::default();
            let mut slabs = e0.panel_slabs(&qt, &[0], &t0, MatView::of(&x), &mut s0);
            slabs.extend(e1.panel_slabs(&qt, &[1], &t1, MatView::of(&x), &mut s1));
            slabs.sort_by_key(|s| (s.gi, s.r));
            let mut got = Mat::zeros(3, 32);
            merge_slabs(&qt, &slabs, &mut got);
            assert_eq!(got.data, want.data, "{payload}: sharded merge not bit-exact");

            // stats: the coordinator charges act_bytes once; everything
            // else sums across shards exactly
            let mut s_sum = DecodeStats::default();
            s_sum.merge(&s0);
            s_sum.merge(&s1);
            s_sum.act_bytes += (x.data.len() + want.data.len()) * 4;
            assert_eq!(s_sum, s_full, "{payload}: shard stats drifted");
        }
    }

    #[test]
    fn peak_decoded_stat_respects_panel_bound() {
        // fixed-width payloads: the decode buffer never exceeds
        // panel_rows × group cols, no matter the batch or thread count
        let (_, qt) = quantized_tensor("rtn", 5);
        let sm = StreamingMatmul::new(4, 4);
        let mut rng = Rng::new(13);
        let x = Mat::random_normal(16, 64, 1.0, &mut rng);
        let mut y = Mat::zeros(16, 32);
        let mut stats = DecodeStats::default();
        sm.matmul(&qt, &x, &mut y, &mut stats);
        assert!(stats.peak_decoded > 0);
        assert!(stats.peak_decoded <= sm.panel_rows * qt.cols);
        assert_eq!(stats.peak_decoded, sm.peak_panel_elems(&qt));
        // the paper's claim: far below whole-layer decode
        assert!(stats.peak_decoded * 10 <= qt.rows * qt.cols);
    }

    #[test]
    fn stats_account_for_code_traffic() {
        let (_, qt) = quantized_tensor("rtn", 6);
        let sm = StreamingMatmul::new(16, 1);
        let mut stats = DecodeStats::default();
        let x = vec![1.0f32; 64];
        sm.matvec(&qt, &x, &mut stats);
        // 2-bit codes over 2048 weights = 512 bytes
        assert_eq!(stats.code_bytes, 2048 * 2 / 8);
        assert_eq!(stats.weights_decoded, 2048);
        assert!(stats.total_bytes() > stats.code_bytes);
    }

    #[test]
    fn streaming_support_matrix() {
        assert!(supports_streaming(&SideInfo::Uniform { scale: 1.0, zero: 0.0 }));
        assert!(supports_streaming(&SideInfo::Lattice {
            d: 8,
            g: vec![0.0; 64],
            mu: 50.0,
            scale: 1.0
        }));
        assert!(!supports_streaming(&SideInfo::Trellis { levels: vec![0.0; 8], states: 4 }));
    }

    #[test]
    fn misrouted_binary_decode_is_a_structured_error_not_a_panic() {
        let side = SideInfo::Binary {
            row_scales: (0..8).map(|i| 0.1 + 0.01 * i as f32).collect(),
            residual_scales: None,
        };
        let mut out = vec![0.0f32; 16];
        let err = decode_codes(&side, 1, &[0i32; 16], &mut out).unwrap_err();
        assert_eq!(err.family, "binary");
        assert!(err.to_string().contains("streaming path"), "{err}");
        // streaming families still decode through the same entry point
        decode_codes(&SideInfo::Uniform { scale: 0.5, zero: 0.25 }, 2, &[1, -1], &mut out[..2])
            .unwrap();
        assert_eq!(&out[..2], &[0.75, -0.25]);
    }

    #[test]
    fn binary_groups_serve_through_the_whole_group_fallback() {
        // a binary group on the serving path must route through the dense
        // fallback (never the panel decoder) and match the oracle bit-exactly
        let codes: Vec<i32> = (0..64).map(|i| (i % 2) - 1).collect();
        let qg = crate::quant::traits::QuantizedGroup {
            method: "binary",
            bits: 1,
            rows: 8,
            cols: 8,
            codes: crate::quant::pack::PackedCodes::pack(&codes, 1).into(),
            side: SideInfo::Binary {
                row_scales: (0..8).map(|i| 0.1 + 0.01 * i as f32).collect(),
                residual_scales: None,
            },
        };
        let qt = QuantizedTensor { name: "bin".into(), rows: 8, cols: 8, groups: vec![(0, 0, qg)] };
        let mut rng = Rng::new(21);
        let x = Mat::random_normal(3, 8, 1.0, &mut rng);
        let want = oracle_matmul(&qt, &x);
        let sm = StreamingMatmul::new(4, 1);
        let mut y = Mat::zeros(3, 8);
        let mut stats = DecodeStats::default();
        sm.matmul(&qt, &x, &mut y, &mut stats);
        assert_eq!(y.data, want.data, "binary fallback not bit-exact");
        assert!(stats.code_bytes > 0);
    }
}
