//! Streaming on-the-fly decoding — the paper's §3.4 runtime contribution:
//! "materialise just a handful of sub-blocks, apply ŵ = F⁻¹(G z) and
//! release the data immediately after use", bounding peak memory at
//! activations + one sub-block panel instead of the whole dequantized layer.
//!
//! [`StreamingMatvec`] computes y = x · Wᵀ_q (paper orientation: quantized
//! tensors store Wᵀ, m×n_in) one group-panel at a time from the packed
//! codes, tracking exact bytes-touched so Table 4's MEM BW column can be
//! reproduced as a bytes-moved model. Correctness oracle: full dequantize +
//! dense matvec (tested for exact equality).

use crate::compand::MuLaw;
use crate::linalg::Mat;
use crate::quant::format::QuantizedTensor;
use crate::quant::pack::code_range;
use crate::quant::traits::{hadamard_inverse, sign_vector, SideInfo};

/// Counters for the bytes-moved model (Table 4 MEM BW).
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeStats {
    /// code payload bytes read — the *true stored* bytes: bit-granular for
    /// fixed-width payloads, chunk-granular (stream + states + escapes +
    /// frequency table) for entropy-coded payloads
    pub code_bytes: usize,
    /// side-info bytes read (FP16-equivalent accounting)
    pub side_bytes: usize,
    /// activation bytes read + written
    pub act_bytes: usize,
    /// decoded weights produced (elements) — never persisted
    pub weights_decoded: usize,
    /// multiply-accumulate count
    pub macs: usize,
}

impl DecodeStats {
    pub fn total_bytes(&self) -> usize {
        self.code_bytes + self.side_bytes + self.act_bytes
    }
}

/// Scratch buffers reused across calls (allocation-free hot loop).
pub struct StreamingMatvec {
    codes_buf: Vec<i32>,
    panel: Vec<f32>,
    /// lattice-decode scratch: codes as f32 blocks (+½) for the blocked
    /// matmul path (§Perf: scalar per-block loops → one (B×d)@(d×d) GEMM)
    zf: Vec<f32>,
    /// rANS chunk-decode scratch (reused across panels and groups)
    rans_scratch: Vec<i32>,
    /// rows per streamed panel (the "handful of sub-blocks")
    pub panel_rows: usize,
}

impl Default for StreamingMatvec {
    fn default() -> Self {
        StreamingMatvec::new(16)
    }
}

impl StreamingMatvec {
    pub fn new(panel_rows: usize) -> StreamingMatvec {
        StreamingMatvec {
            codes_buf: Vec::new(),
            panel: Vec::new(),
            zf: Vec::new(),
            rans_scratch: Vec::new(),
            panel_rows: panel_rows.max(1),
        }
    }

    /// Effective panel rows for one group: `panel_rows`, except rANS
    /// payloads whose chunk rows align — there the panel snaps to whole
    /// chunks so every chunk is decoded (and charged) exactly once per
    /// matvec. This is also the working-set bound `peak_panel_elems`
    /// reports: chunk-granular decode cannot go below one chunk.
    fn effective_panel_rows(&self, g: &crate::quant::traits::QuantizedGroup) -> usize {
        let (m, n) = (g.rows, g.cols.max(1));
        match &g.codes {
            crate::quant::traits::CodePayload::Rans(rc) if rc.chunk_len % n == 0 => {
                let chunk_rows = (rc.chunk_len / n).max(1);
                if chunk_rows >= self.panel_rows {
                    chunk_rows.min(m)
                } else {
                    ((self.panel_rows / chunk_rows) * chunk_rows).min(m)
                }
            }
            _ => self.panel_rows.min(m),
        }
    }

    /// y += decode(qt) · x, streaming panel_rows rows of the (m × n) stored
    /// tensor at a time. x has length n (input dim), y has length m.
    pub fn matvec(
        &mut self,
        qt: &QuantizedTensor,
        x: &[f32],
        y: &mut [f32],
        stats: &mut DecodeStats,
    ) {
        assert_eq!(x.len(), qt.cols, "{}: x len {} != cols {}", qt.name, x.len(), qt.cols);
        assert_eq!(y.len(), qt.rows);
        y.fill(0.0);
        stats.act_bytes += (x.len() + y.len()) * 4;
        for (r0, c0, g) in &qt.groups {
            self.group_matvec_into(g, &x[*c0..*c0 + g.cols], &mut y[*r0..*r0 + g.rows], stats);
        }
    }

    /// Accumulate one group's contribution: y_rows += decode(g) · x_cols.
    fn group_matvec_into(
        &mut self,
        g: &crate::quant::traits::QuantizedGroup,
        x: &[f32],
        y: &mut [f32],
        stats: &mut DecodeStats,
    ) {
        let (m, n) = (g.rows, g.cols);
        stats.side_bytes += g.side_bytes();
        if !supports_streaming(&g.side) {
            // lookup/stateful methods (codebook, trellis, binary) cannot
            // decode from an arbitrary offset: dequantize the whole group —
            // exactly the operational cost the paper charges AQLM-style
            // methods in Table 4.
            let dense = g.dequantize();
            stats.code_bytes += g.codes.payload_bytes();
            stats.weights_decoded += m * n;
            for i in 0..m {
                let row = dense.row(i);
                let mut acc = 0.0f32;
                for (a, b) in row.iter().zip(x.iter()) {
                    acc += a * b;
                }
                y[i] += acc;
            }
            stats.macs += m * n;
            return;
        }
        let pr = self.effective_panel_rows(g);
        self.codes_buf.resize(pr * n, 0);
        self.panel.resize(pr * n, 0.0);
        // expand the rANS decode table once per group, not once per panel
        let rans_table = match &g.codes {
            crate::quant::traits::CodePayload::Rans(rc) => Some(rc.hist.decode_table()),
            _ => None,
        };

        let mut r = 0usize;
        while r < m {
            let rows = pr.min(m - r);
            let count = rows * n;
            match (&g.codes, &rans_table) {
                (crate::quant::traits::CodePayload::Rans(rc), Some(table)) => rc
                    .decode_range_with(
                        r * n,
                        &mut self.codes_buf[..count],
                        table,
                        &mut self.rans_scratch,
                    ),
                _ => g.codes.unpack_range_into(r * n, &mut self.codes_buf[..count]),
            }
            stats.code_bytes += g.codes.range_payload_bytes(r * n, count);
            if let SideInfo::Lattice { d, g: gmat, mu, scale } = &g.side {
                // §Perf fast path: blocked GEMM (B×d)@(d×d) + vectorized
                // μ-law expand instead of per-block scalar loops.
                let d = *d;
                self.zf.resize(count, 0.0);
                for (zf, &c) in self.zf.iter_mut().zip(&self.codes_buf[..count]) {
                    *zf = c as f32 + 0.5;
                }
                let zb = Mat::from_vec(count / d, d, self.zf[..count].to_vec());
                let gm = Mat::from_vec(d, d, gmat.clone());
                let mut vb = Mat::zeros(count / d, d);
                crate::linalg::matrix::matmul_into(&zb, &gm.transpose(), &mut vb);
                let comp = MuLaw::new(*mu);
                comp.inverse_slice(&mut vb.data);
                for (o, v) in self.panel[..count].iter_mut().zip(&vb.data) {
                    *o = scale * v;
                }
            } else {
                decode_codes(
                    &g.side,
                    g.codes.bits(),
                    &self.codes_buf[..count],
                    &mut self.panel[..count],
                );
            }
            stats.weights_decoded += count;
            // y[r..r+rows] += panel · x
            for i in 0..rows {
                let row = &self.panel[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (a, b) in row.iter().zip(x.iter()) {
                    acc += a * b;
                }
                y[r + i] += acc;
            }
            stats.macs += count;
            r += rows;
        }
    }

    /// Peak decoded-weights working set in elements (panel size) — the
    /// quantity the paper claims drops >10× vs layer-at-once decode. For
    /// rANS groups the panel snaps to whole chunks (chunk-granular decode
    /// can't go below one chunk), so the bound reflects the buffers
    /// actually allocated.
    pub fn peak_panel_elems(&self, qt: &QuantizedTensor) -> usize {
        qt.groups
            .iter()
            .map(|(_, _, g)| self.effective_panel_rows(g) * g.cols)
            .max()
            .unwrap_or(0)
    }
}

/// Decode a run of codes into weights for any side-info family. The
/// per-family math matches `QuantizedGroup::dequantize` exactly (tested).
/// `codes` holds whole rows, row-major, row length divisible by d/dim.
fn decode_codes(side: &SideInfo, bits: u8, codes: &[i32], out: &mut [f32]) {
    match side {
        SideInfo::Uniform { scale, zero } => {
            for (o, &c) in out.iter_mut().zip(codes) {
                *o = c as f32 * scale + zero;
            }
        }
        SideInfo::Lattice { d, g, mu, scale } => {
            let d = *d;
            let comp = MuLaw::new(*mu);
            let blocks = codes.len() / d;
            for b in 0..blocks {
                let z = &codes[b * d..(b + 1) * d];
                // half-integer grid: ŵ = scale · F⁻¹(G (z + ½))
                for i in 0..d {
                    let mut acc = 0.0f32;
                    let row = &g[i * d..(i + 1) * d];
                    for (j, &zj) in z.iter().enumerate() {
                        acc += row[j] * (zj as f32 + 0.5);
                    }
                    out[b * d + i] = scale * comp.inverse(acc);
                }
            }
        }
        SideInfo::RotatedLattice { d, scale, sign_seed } => {
            let d = *d;
            let signs = sign_vector(*sign_seed, d);
            let blocks = codes.len() / d;
            let mut y = vec![0.0f32; d];
            for b in 0..blocks {
                for i in 0..d {
                    y[i] = codes[b * d + i] as f32 * 0.5;
                }
                let w = hadamard_inverse(&y);
                for i in 0..d {
                    out[b * d + i] = w[i] * signs[i] * scale;
                }
            }
        }
        SideInfo::Codebook { dim, centers } => {
            let dim = *dim;
            let lo = code_range(bits).0;
            // NB: for codebook methods `codes` are block indices (one per
            // dim-length block); callers pass rows in block units.
            let blocks = codes.len();
            let _ = blocks;
            for (b, &c) in codes.iter().enumerate() {
                let idx = (c - lo) as usize;
                out[b * dim..(b + 1) * dim].copy_from_slice(&centers[idx * dim..(idx + 1) * dim]);
            }
        }
        SideInfo::Trellis { levels, states } => {
            let per = levels.len() / 4;
            let lo = code_range(bits).0;
            let smask = states - 1;
            let mut state = 0usize;
            for (o, &c) in out.iter_mut().zip(codes) {
                let u = ((c - lo) as usize) & 1;
                let j = ((c - lo) as usize) >> 1;
                let subset = ((state & 1) << 1) | u;
                *o = levels[subset * per + j.min(per - 1)];
                state = ((state << 1) | u) & smask;
            }
        }
        SideInfo::Binary { .. } => {
            // binary decode needs row indices for per-row scales; handled by
            // dequantize() — the streaming bench does not cover binary.
            unimplemented!("binary methods are not on the streaming path");
        }
    }
}

/// Streaming decoder caveats per method (documented behaviour):
/// - Lattice/Uniform/RotatedLattice stream exactly.
/// - Codebook streams in block units (the caller must align panels).
/// - Trellis decode is stateful from position 0, so `unpack_range_into`
///   cannot start mid-stream; StreamingMatvec therefore uses panel_rows
///   covering whole groups for TCQ (see `supports_streaming`).
pub fn supports_streaming(side: &SideInfo) -> bool {
    !matches!(side, SideInfo::Trellis { .. } | SideInfo::Binary { .. } | SideInfo::Codebook { .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::RtnQuantizer;
    use crate::config::GlvqConfig;
    use crate::glvq::optimizer::GlvqGroupQuantizer;
    use crate::linalg::Mat;
    use crate::quant::traits::GroupQuantizer;
    use crate::util::rng::Rng;

    fn quantized_tensor(method: &str, seed: u64) -> (Mat, QuantizedTensor) {
        let mut rng = Rng::new(seed);
        let wt = Mat::random_normal(32, 64, 0.05, &mut rng); // (m × n)
        let x = Mat::random_normal(32, 32, 1.0, &mut rng);
        let mut groups = Vec::new();
        for gi in 0..2 {
            let panel = wt.slice(0, 32, gi * 32, (gi + 1) * 32);
            let qg = match method {
                "glvq" => {
                    let mut cfg = GlvqConfig::default();
                    cfg.lattice_dim = 8;
                    cfg.group_size = 32;
                    cfg.iters = 4;
                    GlvqGroupQuantizer::new(cfg).quantize(&panel, &x, 2)
                }
                _ => RtnQuantizer.quantize(&panel, &x, 2),
            };
            groups.push((0usize, gi * 32, qg));
        }
        (wt, QuantizedTensor { name: "t".into(), rows: 32, cols: 64, groups })
    }

    #[test]
    fn streaming_matvec_equals_dense_dequantize_matvec() {
        for method in ["rtn", "glvq"] {
            let (_, qt) = quantized_tensor(method, 3);
            let mut rng = Rng::new(4);
            let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            let dense = qt.dequantize();
            let want = dense.matvec(&x);
            let mut sm = StreamingMatvec::new(8);
            let mut y = vec![0.0f32; 32];
            let mut stats = DecodeStats::default();
            sm.matvec(&qt, &x, &mut y, &mut stats);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{method}: {a} vs {b}");
            }
            assert!(stats.code_bytes > 0 && stats.macs == 32 * 64);
        }
    }

    /// Re-encode every group payload with rANS (`rows_per_chunk` rows per
    /// chunk) — lossless, so all decode paths must agree bit-for-bit.
    fn to_entropy_tensor(qt: &QuantizedTensor, rows_per_chunk: usize) -> QuantizedTensor {
        let mut out = qt.clone();
        for (_, _, g) in &mut out.groups {
            g.codes = g.codes.to_entropy(g.cols * rows_per_chunk.max(1), 4);
        }
        out
    }

    #[test]
    fn streaming_matvec_matches_oracle_on_entropy_payloads() {
        for method in ["rtn", "glvq"] {
            let (_, qt) = quantized_tensor(method, 7);
            let dense = qt.dequantize();
            let mut rng = Rng::new(8);
            let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            let want = dense.matvec(&x);
            // chunking both aligned (8 rows = panel) and misaligned (5 rows)
            for rows_per_chunk in [1usize, 5, 8, 64] {
                let qte = to_entropy_tensor(&qt, rows_per_chunk);
                // lossless re-encode: dequantize is bit-identical
                assert_eq!(qte.dequantize().data, dense.data);
                let mut sm = StreamingMatvec::new(8);
                let mut y = vec![0.0f32; 32];
                let mut stats = DecodeStats::default();
                sm.matvec(&qte, &x, &mut y, &mut stats);
                for (a, b) in y.iter().zip(&want) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "{method}/chunk{rows_per_chunk}: {a} vs {b}"
                    );
                }
                assert!(stats.code_bytes > 0 && stats.macs == 32 * 64);
            }
        }
    }

    #[test]
    fn entropy_code_bytes_reflect_compressed_payload() {
        // skewed codes → the streamed byte count must track the compressed
        // size, which for near-constant codes is far below fixed-width
        let codes = vec![0i32; 64 * 64];
        let qg = crate::quant::traits::QuantizedGroup {
            method: "rtn",
            bits: 4,
            rows: 64,
            cols: 64,
            codes: crate::quant::pack::PackedCodes::pack(&codes, 4).into(),
            side: SideInfo::Uniform { scale: 0.1, zero: 0.0 },
        };
        let fixed_bytes = qg.codes.payload_bytes();
        let mut qge = qg.clone();
        qge.codes = qge.codes.to_entropy(64 * 8, 4);
        let qt = QuantizedTensor {
            name: "e".into(),
            rows: 64,
            cols: 64,
            groups: vec![(0, 0, qge)],
        };
        let mut sm = StreamingMatvec::new(8);
        let mut y = vec![0.0f32; 64];
        let mut stats = DecodeStats::default();
        let x = vec![1.0f32; 64];
        sm.matvec(&qt, &x, &mut y, &mut stats);
        assert!(
            stats.code_bytes < fixed_bytes / 4,
            "compressed traffic {} vs fixed {}",
            stats.code_bytes,
            fixed_bytes
        );
        // panels aligned to chunks → every chunk is charged exactly once
        assert_eq!(stats.code_bytes, qt.groups[0].2.codes.payload_bytes());
    }

    #[test]
    fn panel_size_bounds_peak_memory() {
        let (_, qt) = quantized_tensor("rtn", 5);
        let sm = StreamingMatvec::new(4);
        // 4 rows × 32-col group = 128 elems vs full 32×64 = 2048 → 16×
        assert_eq!(sm.peak_panel_elems(&qt), 4 * 32);
        assert!(sm.peak_panel_elems(&qt) * 10 <= qt.rows * qt.cols);
    }

    #[test]
    fn stats_account_for_code_traffic() {
        let (_, qt) = quantized_tensor("rtn", 6);
        let mut sm = StreamingMatvec::new(16);
        let mut y = vec![0.0f32; 32];
        let mut stats = DecodeStats::default();
        let x = vec![1.0f32; 64];
        sm.matvec(&qt, &x, &mut y, &mut stats);
        // 2-bit codes over 2048 weights = 512 bytes
        assert_eq!(stats.code_bytes, 2048 * 2 / 8);
        assert_eq!(stats.weights_decoded, 2048);
        assert!(stats.total_bytes() > stats.code_bytes);
    }

    #[test]
    fn streaming_support_matrix() {
        assert!(supports_streaming(&SideInfo::Uniform { scale: 1.0, zero: 0.0 }));
        assert!(supports_streaming(&SideInfo::Lattice {
            d: 8,
            g: vec![0.0; 64],
            mu: 50.0,
            scale: 1.0
        }));
        assert!(!supports_streaming(&SideInfo::Trellis { levels: vec![0.0; 8], states: 4 }));
    }
}
