//! Work scheduler: a scoped thread pool with an atomic work queue and
//! deterministic result placement, shared by the quantization pipeline and
//! the streaming decode engine.
//!
//! Group quantization and per-batch panel decode are both embarrassingly
//! parallel, but results must assemble in item order regardless of
//! completion order — [`parallel_map`] guarantees exactly that:
//! `output[i]` is `f(items[i])` no matter which worker ran it. That is what
//! makes [`crate::coordinator::decode_stream::StreamingMatmul`] bit-
//! deterministic across thread counts. Worker panics are surfaced as an
//! Err carrying the index (failure injection is tested).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads: physical parallelism minus one for the
/// coordinator, at least 1, unless overridden by GLVQ_THREADS.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("GLVQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// Map `f` over `items` on `threads` workers; results in input order.
/// The closure receives `(worker, index, item)`: `worker` is a stable id
/// in `0..threads` identifying the thread running the call — callers key
/// per-worker scratch to it so scratch acquisition is contention-free —
/// and `index` is the item's position (`output[index] = f(_, index,
/// &items[index])` no matter which worker ran it).
/// Returns Err((index, message)) if any invocation panicked.
pub fn parallel_map<T, R, F>(
    threads: usize,
    items: &[T],
    f: F,
) -> Result<Vec<R>, (usize, String)>
where
    T: Sync,
    R: Send,
    F: Fn(usize, usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        // inline fast path: no thread spawn, same ordering and panic
        // contract — this is what lets a persistent single-thread shard
        // worker decode without paying a scoped-spawn per call
        let mut out = Vec::with_capacity(n);
        for (i, item) in items.iter().enumerate() {
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0, i, item))) {
                Ok(r) => out.push(r),
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<panic>".into());
                    return Err((i, msg));
                }
            }
        }
        return Ok(out);
    }
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    let failure: Mutex<Option<(usize, String)>> = Mutex::new(None);
    let next = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        let (f, slots, failure, next) = (&f, &slots, &failure, &next);
        for w in 0..threads {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n || failure.lock().unwrap().is_some() {
                    break;
                }
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f(w, i, &items[i])
                }));
                match result {
                    Ok(r) => {
                        slots.lock().unwrap()[i] = Some(r);
                    }
                    Err(e) => {
                        let msg = e
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "<panic>".into());
                        *failure.lock().unwrap() = Some((i, msg));
                        break;
                    }
                }
            });
        }
    });

    if let Some(fail) = failure.into_inner().unwrap() {
        return Err(fail);
    }
    let out: Vec<R> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("all slots filled on success"))
        .collect();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_input_order() {
        let items: Vec<usize> = (0..200).collect();
        let out = parallel_map(8, &items, |_, i, &x| {
            // stagger completion order
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            (i, x * 2)
        })
        .unwrap();
        for (i, (gi, v)) in out.iter().enumerate() {
            assert_eq!(*gi, i);
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn worker_ids_are_bounded_by_thread_count() {
        // worker ids are what decode engines key their scratch slots to:
        // every id must fall in 0..threads, and with one thread it is 0
        let items: Vec<usize> = (0..100).collect();
        for threads in [1usize, 2, 4, 8] {
            let workers = parallel_map(threads, &items, |w, _, _| w).unwrap();
            assert!(workers.iter().all(|&w| w < threads), "threads={threads}: {workers:?}");
            if threads == 1 {
                assert!(workers.iter().all(|&w| w == 0));
            }
        }
    }

    #[test]
    fn single_thread_degenerates_to_sequential() {
        let items = vec![1, 2, 3];
        let out = parallel_map(1, &items, |_, _, &x| x + 1).unwrap();
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input_ok() {
        let items: Vec<u32> = vec![];
        let out = parallel_map(4, &items, |_, _, &x| x).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn worker_panic_reported_with_index() {
        let items: Vec<usize> = (0..50).collect();
        let err = parallel_map(4, &items, |_, _, &x| {
            if x == 33 {
                panic!("boom at {x}");
            }
            x
        })
        .unwrap_err();
        assert_eq!(err.0, 33);
        assert!(err.1.contains("boom"), "{}", err.1);
    }

    #[test]
    fn deterministic_results_across_thread_counts() {
        let items: Vec<usize> = (0..64).collect();
        let a = parallel_map(1, &items, |_, _, &x| x * x).unwrap();
        let b = parallel_map(7, &items, |_, _, &x| x * x).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
