//! Serving metrics: counters + fixed-capacity reservoir histograms giving
//! p50/p95/p99 latencies, queue wait, time-to-first-token and
//! step-batch occupancy for the server (lockstep and continuous modes)
//! and the serving benches, plus the cumulative streaming-decode traffic
//! ([`crate::coordinator::decode_stream::DecodeStats`]) when the backend
//! executes from compressed weights, and KV-cache occupancy/quantization
//! counters ([`crate::kvcache::KvCacheStats`]) when it serves through the
//! paged cache.
//!
//! [`ServerMetrics::snapshot`] freezes everything into an
//! [`crate::obs::MetricsSnapshot`], the one source for all three export
//! formats: the human [`ServerMetrics::report`] line (rendered by
//! [`human_line`]), structured JSON, and Prometheus text exposition.

use std::time::Instant;

use crate::coordinator::decode_stream::DecodeStats;
use crate::kvcache::KvCacheStats;
use crate::obs::{Mark, MetricValue, MetricsSnapshot, Registry, RequestTimeline};
use crate::serving::queue::RejectionCounts;
use crate::shard::{imbalance, ShardStat};
use crate::spec::SpecStats;

/// Streaming latency histogram: a fixed-capacity uniform reservoir kept
/// sorted by insertion (exact quantiles for ≤ capacity samples, uniform
/// subsample beyond), plus a running sum/count over the *full* stream so
/// [`LatencyHist::mean`] is exact regardless of reservoir eviction.
/// Quantile reads are O(1) indexed lookups — no per-call clone or sort.
#[derive(Clone, Debug)]
pub struct LatencyHist {
    /// reservoir, maintained in ascending order
    samples: Vec<f64>,
    capacity: usize,
    seen: usize,
    /// sum over every recorded value, not just the surviving reservoir
    sum: f64,
    rng_state: u64,
}

impl LatencyHist {
    pub fn new(capacity: usize) -> LatencyHist {
        LatencyHist {
            samples: Vec::with_capacity(capacity),
            capacity,
            seen: 0,
            sum: 0.0,
            rng_state: 0x9E37,
        }
    }

    pub fn record(&mut self, value_ms: f64) {
        self.seen += 1;
        self.sum += value_ms;
        if self.samples.len() < self.capacity {
            let pos = self.samples.partition_point(|&x| x < value_ms);
            self.samples.insert(pos, value_ms);
        } else {
            // Reservoir eviction: admit with probability capacity/seen,
            // evicting a uniformly random resident — the same stationary
            // distribution as algorithm-R slot replacement, expressed on
            // the sorted reservoir.
            self.rng_state = self
                .rng_state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = (self.rng_state >> 33) as usize % self.seen;
            if j < self.capacity {
                self.samples.remove(j);
                let pos = self.samples.partition_point(|&x| x < value_ms);
                self.samples.insert(pos, value_ms);
            }
        }
    }

    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let pos = (q.clamp(0.0, 1.0) * (self.samples.len() - 1) as f64).round() as usize;
        self.samples[pos]
    }

    pub fn count(&self) -> usize {
        self.seen
    }

    /// Exact mean of the full stream (running sum / count), unaffected by
    /// which samples survive the reservoir.
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum / self.seen as f64
        }
    }

    /// Sum over the full stream.
    pub fn sum(&self) -> f64 {
        self.sum
    }
}

/// Register a histogram as a summary metric: p50/p95/p99 plus the full
/// stream sum and count.
fn register_hist(reg: &mut Registry, name: &str, h: &LatencyHist) {
    reg.summary(
        name,
        vec![(0.5, h.quantile(0.5)), (0.95, h.quantile(0.95)), (0.99, h.quantile(0.99))],
        h.sum(),
        h.count() as u64,
    );
}

/// Register a raw value list as a summary metric (used for per-request
/// timeline attributions). No-op when empty.
fn register_dist(reg: &mut Registry, name: &str, vals: &mut Vec<f64>) {
    if vals.is_empty() {
        return;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |q: f64| vals[(q * (vals.len() - 1) as f64).round() as usize];
    reg.summary(
        name,
        vec![(0.5, q(0.5)), (0.95, q(0.95))],
        vals.iter().sum(),
        vals.len() as u64,
    );
}

/// Aggregated server metrics.
#[derive(Debug)]
pub struct ServerMetrics {
    pub started: Instant,
    pub requests: usize,
    pub tokens_out: usize,
    pub batches: usize,
    /// end-to-end request latency (submit → response), ms
    pub latency: LatencyHist,
    /// submit → admission wait, ms (lockstep: submit → batch drain)
    pub queue_wait: LatencyHist,
    /// submit → first emitted/scored token, ms — the latency continuous
    /// batching exists to protect
    pub ttft: LatencyHist,
    /// sequences per scheduler step (continuous mode) — quantiles show
    /// how full the step batches ran
    pub seqs_per_step: LatencyHist,
    /// continuous-scheduler iterations executed
    pub sched_steps: usize,
    /// prefill chunks fed (continuous mode chunked prefill)
    pub prefill_chunks: usize,
    /// prompt tokens fed through prefill chunks
    pub prefill_tokens: usize,
    /// sequences spilled out of the KV arena under page pressure
    pub preemptions: usize,
    /// preempted sequences resumed
    pub resumes: usize,
    /// requests refused with structured backpressure, tallied per
    /// [`crate::serving::Backpressure`] variant — `queue_full` means the
    /// engine is saturated, the rest mean the request itself is infeasible
    pub rejections: RejectionCounts,
    /// admitted requests that claimed a shared KV prefix instead of
    /// re-prefilling it (prefix sharing on)
    pub prefix_hits: usize,
    /// prompt tokens satisfied from shared prefix pages — tokens the
    /// prefill path never had to feed
    pub prefix_tokens: usize,
    /// cumulative streaming-decode traffic, when the backend serves from
    /// compressed weights (None for dense/PJRT backends)
    pub decode: Option<DecodeStats>,
    /// KV-cache occupancy / quantization / decode counters, when the
    /// backend serves through the paged cache (None otherwise)
    pub kv_cache: Option<KvCacheStats>,
    /// per-shard decode/busy counters, when the backend executes
    /// tensor-parallel over the shard executor (None otherwise)
    pub shards: Option<Vec<ShardStat>>,
    /// draft/verify counters, when the backend decodes speculatively
    /// (None otherwise) — source of the `accept_rate` report section
    pub spec: Option<SpecStats>,
    /// per-request lifecycle timelines recorded by the continuous
    /// scheduler (empty in lockstep mode) — source of the
    /// `request_{queue,prefill,decode}_ms` attribution summaries
    pub timelines: Vec<RequestTimeline>,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        ServerMetrics {
            started: Instant::now(),
            requests: 0,
            tokens_out: 0,
            batches: 0,
            latency: LatencyHist::new(4096),
            queue_wait: LatencyHist::new(4096),
            ttft: LatencyHist::new(4096),
            seqs_per_step: LatencyHist::new(4096),
            sched_steps: 0,
            prefill_chunks: 0,
            prefill_tokens: 0,
            preemptions: 0,
            resumes: 0,
            rejections: RejectionCounts::default(),
            prefix_hits: 0,
            prefix_tokens: 0,
            decode: None,
            kv_cache: None,
            shards: None,
            spec: None,
            timelines: Vec::new(),
        }
    }
}

impl ServerMetrics {
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.tokens_out as f64 / secs
        } else {
            0.0
        }
    }

    /// Freeze every counter, histogram and subsystem stat into a typed
    /// [`MetricsSnapshot`]. Everything `report()` prints is derived from
    /// this snapshot, so the human line, the JSON export and the
    /// Prometheus exposition can never disagree.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut reg = Registry::new();
        reg.counter("requests_total", self.requests as u64);
        reg.counter("tokens_out_total", self.tokens_out as u64);
        reg.counter("batches_total", self.batches as u64);
        reg.gauge("uptime_seconds", self.started.elapsed().as_secs_f64());
        reg.gauge("tokens_per_sec", self.tokens_per_sec());
        register_hist(&mut reg, "request_latency_ms", &self.latency);
        register_hist(&mut reg, "queue_wait_ms", &self.queue_wait);
        register_hist(&mut reg, "ttft_ms", &self.ttft);
        register_hist(&mut reg, "seqs_per_step", &self.seqs_per_step);
        reg.counter("sched_steps_total", self.sched_steps as u64);
        reg.counter("prefill_chunks_total", self.prefill_chunks as u64);
        reg.counter("prefill_tokens_total", self.prefill_tokens as u64);
        reg.counter("preemptions_total", self.preemptions as u64);
        reg.counter("resumes_total", self.resumes as u64);
        for (reason, n) in self.rejections.breakdown() {
            reg.counter_with("rejections_total", &[("reason", reason)], n as u64);
        }
        reg.counter("prefix_hits_total", self.prefix_hits as u64);
        reg.counter("prefix_tokens_total", self.prefix_tokens as u64);
        if let Some(d) = &self.decode {
            reg.counter("decoded_bytes_total", d.total_bytes() as u64);
            reg.counter("decode_code_bytes_total", d.code_bytes as u64);
            reg.counter("decode_side_bytes_total", d.side_bytes as u64);
            reg.counter("decode_act_bytes_total", d.act_bytes as u64);
            reg.counter("decode_weights_total", d.weights_decoded as u64);
            reg.counter("decode_macs_total", d.macs as u64);
            reg.gauge("peak_panel_elems", d.peak_decoded as f64);
        }
        if let Some(c) = &self.kv_cache {
            reg.gauge("kv_pages_in_use", c.pages_in_use as f64);
            reg.gauge("kv_peak_pages", c.peak_pages as f64);
            reg.gauge("kv_hot_pages", c.hot_pages as f64);
            reg.gauge("kv_bytes_in_use", c.bytes_in_use as f64);
            reg.counter("kv_pages_quantized_total", c.pages_quantized as u64);
            reg.counter("kv_appended_rows_total", c.appended_rows as u64);
            reg.counter("kv_decoded_bytes_total", c.decoded_bytes as u64);
            reg.counter("kv_quantized_payload_bytes_total", c.quantized_payload_bytes as u64);
            reg.counter("kv_pages_spilled_total", c.pages_spilled as u64);
            reg.counter("kv_pages_restored_total", c.pages_restored as u64);
            reg.gauge("kv_shared_pages", c.shared_pages as f64);
            reg.gauge("kv_shared_nodes", c.shared_nodes as f64);
            reg.counter("kv_prefix_lookups_total", c.prefix_lookups as u64);
            reg.counter("kv_prefix_hits_total", c.prefix_hits as u64);
            reg.counter("kv_prefix_hit_rows_total", c.prefix_hit_rows as u64);
            reg.counter("kv_cow_splits_total", c.cow_splits as u64);
            reg.counter("kv_prefix_evictions_total", c.prefix_evictions as u64);
        }
        if let Some(s) = &self.spec {
            reg.counter("spec_drafted_total", s.drafted);
            reg.counter("spec_accepted_total", s.accepted);
            reg.counter("spec_rounds_total", s.rounds);
            reg.counter("spec_verify_calls_total", s.verify_calls);
            reg.counter("spec_rollback_rows_total", s.rollback_rows);
            reg.gauge("spec_accept_rate", s.accept_rate());
        }
        if let Some(s) = &self.shards {
            reg.gauge("shard_count", s.len() as f64);
            reg.gauge("shard_imbalance", imbalance(s));
            reg.counter(
                "shard_decoded_bytes_total",
                s.iter().map(|p| p.total_bytes).sum::<usize>() as u64,
            );
            reg.counter("shard_jobs_total", s.iter().map(|p| p.jobs).sum::<usize>() as u64);
            reg.counter("shard_busy_ns_total", s.iter().map(|p| p.busy_ns).sum::<u64>());
        }
        if !self.timelines.is_empty() {
            let mut queue: Vec<f64> = Vec::with_capacity(self.timelines.len());
            let mut prefill: Vec<f64> = Vec::with_capacity(self.timelines.len());
            let mut decode: Vec<f64> = Vec::with_capacity(self.timelines.len());
            let mut preempted = 0u64;
            for t in &self.timelines {
                let b = t.breakdown();
                queue.push(b.queue_ns as f64 / 1e6);
                prefill.push(b.prefill_ns as f64 / 1e6);
                decode.push(b.decode_ns as f64 / 1e6);
                if t.count(Mark::Preempt) > 0 {
                    preempted += 1;
                }
            }
            register_dist(&mut reg, "request_queue_ms", &mut queue);
            register_dist(&mut reg, "request_prefill_ms", &mut prefill);
            register_dist(&mut reg, "request_decode_ms", &mut decode);
            reg.counter("timelines_recorded_total", self.timelines.len() as u64);
            reg.counter("timelines_preempted_total", preempted);
        }
        reg.finish()
    }

    /// One-line human summary — rendered from [`ServerMetrics::snapshot`]
    /// via [`human_line`].
    pub fn report(&self) -> String {
        human_line(&self.snapshot())
    }
}

/// Render the canonical one-line human report from a metrics snapshot.
/// Section presence mirrors which subsystems registered: the scheduler
/// section appears once steps ran, decode/KV/shard sections appear when
/// those backends reported.
pub fn human_line(snap: &MetricsSnapshot) -> String {
    let mut out = format!(
        "requests={} tokens={} batches={} tok/s={:.1} p50={:.1}ms p95={:.1}ms p99={:.1}ms",
        snap.counter("requests_total"),
        snap.counter("tokens_out_total"),
        snap.counter("batches_total"),
        snap.gauge("tokens_per_sec"),
        snap.quantile("request_latency_ms", 0.5),
        snap.quantile("request_latency_ms", 0.95),
        snap.quantile("request_latency_ms", 0.99),
    );
    if snap.summary_count("ttft_ms") > 0 {
        out.push_str(&format!(
            " ttft_p50={:.1}ms ttft_p95={:.1}ms queue_p50={:.1}ms",
            snap.quantile("ttft_ms", 0.5),
            snap.quantile("ttft_ms", 0.95),
            snap.quantile("queue_wait_ms", 0.5),
        ));
    }
    if snap.counter("sched_steps_total") > 0 {
        out.push_str(&format!(
            " steps={} seqs/step_p50={:.1} prefill_chunks={} preempt={} resume={} rejected={}",
            snap.counter("sched_steps_total"),
            snap.quantile("seqs_per_step", 0.5),
            snap.counter("prefill_chunks_total"),
            snap.counter("preemptions_total"),
            snap.counter("resumes_total"),
            snap.counter_family("rejections_total"),
        ));
        // per-reason breakdown, nonzero reasons only: the operational
        // signal is whether refusals were saturation (queue_full) or
        // infeasible requests (everything else)
        if snap.counter_family("rejections_total") > 0 {
            let mut parts: Vec<String> = Vec::new();
            for (name, v) in snap.entries() {
                let reason = name
                    .strip_prefix("rejections_total{reason=\"")
                    .and_then(|r| r.strip_suffix("\"}"));
                if let (Some(reason), MetricValue::Counter(c)) = (reason, v) {
                    if *c > 0 {
                        parts.push(format!("{reason}={c}"));
                    }
                }
            }
            if !parts.is_empty() {
                out.push_str(&format!("({})", parts.join(",")));
            }
        }
    }
    if snap.has("peak_panel_elems") {
        out.push_str(&format!(
            " decoded={:.2}MB peak_panel={}elems",
            snap.counter("decoded_bytes_total") as f64 / 1e6,
            snap.gauge("peak_panel_elems"),
        ));
    }
    if snap.has("kv_pages_in_use") {
        out.push_str(&format!(
            " kv_pages={}(peak {}) kv_quantized={} kv_decoded={:.2}MB",
            snap.gauge("kv_pages_in_use"),
            snap.gauge("kv_peak_pages"),
            snap.counter("kv_pages_quantized_total"),
            snap.counter("kv_decoded_bytes_total") as f64 / 1e6,
        ));
    }
    if snap.counter("kv_prefix_lookups_total") > 0 {
        let lookups = snap.counter("kv_prefix_lookups_total");
        let hits = snap.counter("kv_prefix_hits_total");
        out.push_str(&format!(
            " prefix_hit_rate={:.2} prefix_rows={} shared_pages={} cow_splits={} prefix_evict={}",
            hits as f64 / lookups as f64,
            snap.counter("kv_prefix_hit_rows_total"),
            snap.gauge("kv_shared_pages"),
            snap.counter("kv_cow_splits_total"),
            snap.counter("kv_prefix_evictions_total"),
        ));
    }
    if snap.counter("spec_drafted_total") > 0 {
        out.push_str(&format!(
            " accept_rate={:.2} drafted={} accepted={} spec_rounds={} rollback_rows={}",
            snap.gauge("spec_accept_rate"),
            snap.counter("spec_drafted_total"),
            snap.counter("spec_accepted_total"),
            snap.counter("spec_rounds_total"),
            snap.counter("spec_rollback_rows_total"),
        ));
    }
    if snap.has("shard_count") {
        out.push_str(&format!(
            " shards={} shard_imbalance={:.2}x shard_decoded={:.2}MB",
            snap.gauge("shard_count"),
            snap.gauge("shard_imbalance"),
            snap.counter("shard_decoded_bytes_total") as f64 / 1e6,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_distribution() {
        let mut h = LatencyHist::new(1000);
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.quantile(0.5) - 50.0).abs() <= 2.0);
        assert!((h.quantile(0.95) - 95.0).abs() <= 2.0);
        assert!((h.mean() - 50.5).abs() < 0.6);
    }

    #[test]
    fn reservoir_keeps_capacity_bound() {
        let mut h = LatencyHist::new(64);
        for i in 0..10_000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 10_000);
        assert!(h.samples.len() <= 64);
        // median of uniform 0..10000 should be near 5000
        assert!((h.quantile(0.5) - 5000.0).abs() < 1500.0);
    }

    #[test]
    fn empty_hist_is_zero() {
        let h = LatencyHist::new(8);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.sum(), 0.0);
    }

    #[test]
    fn quantile_is_stable_across_repeated_calls() {
        // recorded out of order, far beyond capacity, then interleaved reads
        let mut h = LatencyHist::new(32);
        for i in 0..500 {
            h.record(((i * 7919) % 1000) as f64);
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        for _ in 0..10 {
            assert_eq!(h.quantile(0.95), p95);
            assert_eq!(h.quantile(0.5), p50);
            assert_eq!(h.quantile(0.99), p99);
        }
        // the reservoir is genuinely sorted: quantiles are monotone in q
        assert!(p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn mean_is_exact_for_skewed_stream_beyond_capacity() {
        // tiny reservoir, heavily skewed stream: 999 cheap requests and
        // one catastrophic one. The reservoir almost certainly loses the
        // outlier; the running sum must not.
        let mut h = LatencyHist::new(8);
        for _ in 0..999 {
            h.record(1.0);
        }
        h.record(1001.0);
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 2.0).abs() < 1e-9, "mean={}", h.mean());
        assert!((h.sum() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn report_includes_scheduler_section_when_present() {
        let mut m = ServerMetrics::default();
        assert!(!m.report().contains("steps="), "no scheduler section when idle");
        assert!(!m.report().contains("ttft_p50"), "no ttft section before first token");
        m.ttft.record(12.0);
        m.queue_wait.record(1.5);
        m.sched_steps = 7;
        m.seqs_per_step.record(3.0);
        m.prefill_chunks = 4;
        m.preemptions = 2;
        m.resumes = 2;
        m.rejections.queue_full = 1;
        m.rejections.context_overflow = 2;
        let r = m.report();
        assert!(r.contains("ttft_p50=12.0ms"), "{r}");
        assert!(r.contains("steps=7"), "{r}");
        assert!(r.contains("preempt=2"), "{r}");
        assert!(r.contains("resume=2"), "{r}");
        assert!(r.contains("rejected=3(queue_full=1,context_overflow=2)"), "{r}");
    }

    #[test]
    fn report_includes_shard_section_when_present() {
        let mut m = ServerMetrics::default();
        assert!(!m.report().contains("shards="));
        m.shards = Some(vec![
            ShardStat { busy_ns: 300, total_bytes: 1_500_000, ..Default::default() },
            ShardStat { busy_ns: 100, total_bytes: 500_000, ..Default::default() },
        ]);
        let r = m.report();
        assert!(r.contains("shards=2"), "{r}");
        assert!(r.contains("shard_imbalance=1.50x"), "{r}");
        assert!(r.contains("shard_decoded=2.00MB"), "{r}");
    }

    #[test]
    fn report_includes_kv_cache_section_when_present() {
        let mut m = ServerMetrics::default();
        assert!(!m.report().contains("kv_pages"));
        m.kv_cache = Some(KvCacheStats {
            pages_in_use: 2,
            peak_pages: 5,
            pages_quantized: 3,
            decoded_bytes: 1_000_000,
            ..Default::default()
        });
        let r = m.report();
        assert!(r.contains("kv_pages=2(peak 5)"), "{r}");
        assert!(r.contains("kv_quantized=3"), "{r}");
        assert!(r.contains("kv_decoded=1.00MB"), "{r}");
    }

    #[test]
    fn report_includes_spec_section_when_present() {
        let mut m = ServerMetrics::default();
        assert!(!m.report().contains("accept_rate="), "no spec section for plain backends");
        m.spec = Some(SpecStats {
            drafted: 10,
            accepted: 5,
            rounds: 3,
            verify_calls: 3,
            rollback_rows: 5,
        });
        let r = m.report();
        assert!(r.contains("accept_rate=0.50"), "{r}");
        assert!(r.contains("drafted=10"), "{r}");
        assert!(r.contains("accepted=5"), "{r}");
        assert!(r.contains("spec_rounds=3"), "{r}");
        assert!(r.contains("rollback_rows=5"), "{r}");
    }

    #[test]
    fn snapshot_carries_every_report_counter() {
        let mut m = ServerMetrics::default();
        m.requests = 3;
        m.tokens_out = 41;
        m.batches = 2;
        m.latency.record(10.0);
        m.ttft.record(12.0);
        m.queue_wait.record(1.5);
        m.sched_steps = 7;
        m.seqs_per_step.record(3.0);
        m.prefill_chunks = 4;
        m.prefill_tokens = 90;
        m.preemptions = 2;
        m.resumes = 2;
        m.rejections.count(&crate::serving::Backpressure::EmptyPrompt);
        m.decode = Some(DecodeStats { code_bytes: 100, peak_decoded: 64, ..Default::default() });
        m.kv_cache = Some(KvCacheStats { pages_in_use: 2, peak_pages: 5, ..Default::default() });
        m.shards = Some(vec![ShardStat { busy_ns: 10, total_bytes: 50, ..Default::default() }]);
        m.spec = Some(SpecStats {
            drafted: 8,
            accepted: 6,
            rounds: 2,
            verify_calls: 2,
            rollback_rows: 2,
        });
        let mut t = RequestTimeline::new(0);
        t.mark(Mark::Admit);
        t.mark(Mark::FirstToken);
        t.mark(Mark::Finish);
        m.timelines.push(t);

        let snap = m.snapshot();
        // every counter the human line exposes is present in the snapshot
        for name in [
            "requests_total",
            "tokens_out_total",
            "batches_total",
            "sched_steps_total",
            "prefill_chunks_total",
            "preemptions_total",
            "resumes_total",
            "rejections_total{reason=\"empty_prompt\"}",
            "decoded_bytes_total",
            "kv_pages_quantized_total",
            "kv_decoded_bytes_total",
            "shard_decoded_bytes_total",
            "spec_drafted_total",
            "spec_accepted_total",
            "spec_rounds_total",
            "spec_verify_calls_total",
            "spec_rollback_rows_total",
            "timelines_recorded_total",
        ] {
            assert!(snap.has(name), "snapshot missing {name}");
        }
        for name in [
            "tokens_per_sec",
            "peak_panel_elems",
            "kv_pages_in_use",
            "kv_peak_pages",
            "shard_count",
            "spec_accept_rate",
        ] {
            assert!(snap.has(name), "snapshot missing gauge {name}");
        }
        for name in ["request_latency_ms", "ttft_ms", "queue_wait_ms", "seqs_per_step"] {
            assert!(snap.has(name), "snapshot missing summary {name}");
        }
        assert_eq!(snap.counter("requests_total"), 3);
        assert_eq!(snap.counter_family("rejections_total"), 1);
        assert_eq!(snap.counter_labeled("rejections_total", &[("reason", "empty_prompt")]), 1);
        assert_eq!(snap.summary_count("ttft_ms"), 1);
        assert!(snap.has("request_queue_ms"), "timeline attribution summary");
        // the human line renders from the snapshot alone
        let line = human_line(&snap);
        assert!(line.starts_with("requests=3 tokens=41 batches=2"), "{line}");
        assert!(line.contains("steps=7"), "{line}");
        assert!(line.contains("kv_pages=2(peak 5)"), "{line}");
        assert!(line.contains("shards=1"), "{line}");
        assert!(line.contains("accept_rate=0.75"), "{line}");
        // and both structured exports accept it
        let json = snap.to_json();
        assert_eq!(crate::util::json::Json::parse(&json.to_string()).unwrap(), json);
        crate::obs::registry::validate_prometheus(&snap.to_prometheus()).unwrap();
    }
}
