//! Serving metrics: counters + fixed-capacity reservoir histograms giving
//! p50/p95/p99 latencies, queue wait, time-to-first-token and
//! step-batch occupancy for the server (lockstep and continuous modes)
//! and the serving benches, plus the cumulative streaming-decode traffic
//! ([`crate::coordinator::decode_stream::DecodeStats`]) when the backend
//! executes from compressed weights, and KV-cache occupancy/quantization
//! counters ([`crate::kvcache::KvCacheStats`]) when it serves through the
//! paged cache.

use std::time::Instant;

use crate::coordinator::decode_stream::DecodeStats;
use crate::kvcache::KvCacheStats;
use crate::shard::{imbalance, ShardStat};

/// Streaming latency histogram (reservoir of raw samples; exact quantiles
/// for ≤ capacity samples, uniform subsample beyond).
#[derive(Clone, Debug)]
pub struct LatencyHist {
    samples: Vec<f64>,
    capacity: usize,
    seen: usize,
    rng_state: u64,
}

impl LatencyHist {
    pub fn new(capacity: usize) -> LatencyHist {
        LatencyHist { samples: Vec::with_capacity(capacity), capacity, seen: 0, rng_state: 0x9E37 }
    }

    pub fn record(&mut self, value_ms: f64) {
        self.seen += 1;
        if self.samples.len() < self.capacity {
            self.samples.push(value_ms);
        } else {
            // reservoir replacement
            self.rng_state = self
                .rng_state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = (self.rng_state >> 33) as usize % self.seen;
            if j < self.capacity {
                self.samples[j] = value_ms;
            }
        }
    }

    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (q.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
        v[pos]
    }

    pub fn count(&self) -> usize {
        self.seen
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

/// Aggregated server metrics.
#[derive(Debug)]
pub struct ServerMetrics {
    pub started: Instant,
    pub requests: usize,
    pub tokens_out: usize,
    pub batches: usize,
    /// end-to-end request latency (submit → response), ms
    pub latency: LatencyHist,
    /// submit → admission wait, ms (lockstep: submit → batch drain)
    pub queue_wait: LatencyHist,
    /// submit → first emitted/scored token, ms — the latency continuous
    /// batching exists to protect
    pub ttft: LatencyHist,
    /// sequences per scheduler step (continuous mode) — quantiles show
    /// how full the step batches ran
    pub seqs_per_step: LatencyHist,
    /// continuous-scheduler iterations executed
    pub sched_steps: usize,
    /// prefill chunks fed (continuous mode chunked prefill)
    pub prefill_chunks: usize,
    /// prompt tokens fed through prefill chunks
    pub prefill_tokens: usize,
    /// sequences spilled out of the KV arena under page pressure
    pub preemptions: usize,
    /// preempted sequences resumed
    pub resumes: usize,
    /// requests refused with structured backpressure
    pub rejections: usize,
    /// cumulative streaming-decode traffic, when the backend serves from
    /// compressed weights (None for dense/PJRT backends)
    pub decode: Option<DecodeStats>,
    /// KV-cache occupancy / quantization / decode counters, when the
    /// backend serves through the paged cache (None otherwise)
    pub kv_cache: Option<KvCacheStats>,
    /// per-shard decode/busy counters, when the backend executes
    /// tensor-parallel over the shard executor (None otherwise)
    pub shards: Option<Vec<ShardStat>>,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        ServerMetrics {
            started: Instant::now(),
            requests: 0,
            tokens_out: 0,
            batches: 0,
            latency: LatencyHist::new(4096),
            queue_wait: LatencyHist::new(4096),
            ttft: LatencyHist::new(4096),
            seqs_per_step: LatencyHist::new(4096),
            sched_steps: 0,
            prefill_chunks: 0,
            prefill_tokens: 0,
            preemptions: 0,
            resumes: 0,
            rejections: 0,
            decode: None,
            kv_cache: None,
            shards: None,
        }
    }
}

impl ServerMetrics {
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.tokens_out as f64 / secs
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "requests={} tokens={} batches={} tok/s={:.1} p50={:.1}ms p95={:.1}ms p99={:.1}ms",
            self.requests,
            self.tokens_out,
            self.batches,
            self.tokens_per_sec(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.95),
            self.latency.quantile(0.99),
        );
        if self.ttft.count() > 0 {
            out.push_str(&format!(
                " ttft_p50={:.1}ms ttft_p95={:.1}ms queue_p50={:.1}ms",
                self.ttft.quantile(0.5),
                self.ttft.quantile(0.95),
                self.queue_wait.quantile(0.5),
            ));
        }
        if self.sched_steps > 0 {
            out.push_str(&format!(
                " steps={} seqs/step_p50={:.1} prefill_chunks={} preempt={} resume={} rejected={}",
                self.sched_steps,
                self.seqs_per_step.quantile(0.5),
                self.prefill_chunks,
                self.preemptions,
                self.resumes,
                self.rejections,
            ));
        }
        if let Some(d) = &self.decode {
            out.push_str(&format!(
                " decoded={:.2}MB peak_panel={}elems",
                d.total_bytes() as f64 / 1e6,
                d.peak_decoded
            ));
        }
        if let Some(c) = &self.kv_cache {
            out.push_str(&format!(
                " kv_pages={}(peak {}) kv_quantized={} kv_decoded={:.2}MB",
                c.pages_in_use,
                c.peak_pages,
                c.pages_quantized,
                c.decoded_bytes as f64 / 1e6
            ));
        }
        if let Some(s) = &self.shards {
            let decoded: usize = s.iter().map(|p| p.total_bytes).sum();
            out.push_str(&format!(
                " shards={} shard_imbalance={:.2}x shard_decoded={:.2}MB",
                s.len(),
                imbalance(s),
                decoded as f64 / 1e6
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_distribution() {
        let mut h = LatencyHist::new(1000);
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.quantile(0.5) - 50.0).abs() <= 2.0);
        assert!((h.quantile(0.95) - 95.0).abs() <= 2.0);
        assert!((h.mean() - 50.5).abs() < 0.6);
    }

    #[test]
    fn reservoir_keeps_capacity_bound() {
        let mut h = LatencyHist::new(64);
        for i in 0..10_000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 10_000);
        assert!(h.samples.len() <= 64);
        // median of uniform 0..10000 should be near 5000
        assert!((h.quantile(0.5) - 5000.0).abs() < 1500.0);
    }

    #[test]
    fn empty_hist_is_zero() {
        let h = LatencyHist::new(8);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn report_includes_scheduler_section_when_present() {
        let mut m = ServerMetrics::default();
        assert!(!m.report().contains("steps="), "no scheduler section when idle");
        assert!(!m.report().contains("ttft_p50"), "no ttft section before first token");
        m.ttft.record(12.0);
        m.queue_wait.record(1.5);
        m.sched_steps = 7;
        m.seqs_per_step.record(3.0);
        m.prefill_chunks = 4;
        m.preemptions = 2;
        m.resumes = 2;
        m.rejections = 1;
        let r = m.report();
        assert!(r.contains("ttft_p50=12.0ms"), "{r}");
        assert!(r.contains("steps=7"), "{r}");
        assert!(r.contains("preempt=2"), "{r}");
        assert!(r.contains("resume=2"), "{r}");
        assert!(r.contains("rejected=1"), "{r}");
    }

    #[test]
    fn report_includes_shard_section_when_present() {
        let mut m = ServerMetrics::default();
        assert!(!m.report().contains("shards="));
        m.shards = Some(vec![
            ShardStat { busy_ns: 300, total_bytes: 1_500_000, ..Default::default() },
            ShardStat { busy_ns: 100, total_bytes: 500_000, ..Default::default() },
        ]);
        let r = m.report();
        assert!(r.contains("shards=2"), "{r}");
        assert!(r.contains("shard_imbalance=1.50x"), "{r}");
        assert!(r.contains("shard_decoded=2.00MB"), "{r}");
    }

    #[test]
    fn report_includes_kv_cache_section_when_present() {
        let mut m = ServerMetrics::default();
        assert!(!m.report().contains("kv_pages"));
        m.kv_cache = Some(KvCacheStats {
            pages_in_use: 2,
            peak_pages: 5,
            pages_quantized: 3,
            decoded_bytes: 1_000_000,
            ..Default::default()
        });
        let r = m.report();
        assert!(r.contains("kv_pages=2(peak 5)"), "{r}");
        assert!(r.contains("kv_quantized=3"), "{r}");
        assert!(r.contains("kv_decoded=1.00MB"), "{r}");
    }
}
