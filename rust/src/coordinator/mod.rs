//! L3 coordinator: the runtime system around the quantizers.
//!
//! - [`scheduler`] — thread-pool work queue with deterministic reduction
//!   (drives both the quantization pipeline and the streaming decode
//!   engine),
//! - [`decode_stream`] — the paper's §3.4 on-the-fly decoding as a
//!   batched, multi-threaded serving engine
//!   ([`decode_stream::StreamingMatmul`]): decode a panel once per batch,
//!   matmul, release (peak-memory bound),
//! - [`server`] — batched LM request loop (generate/score) with lockstep
//!   batch stepping, over dense weights, a compressed `.glvq` container
//!   ([`server::StreamingNativeBackend`]), or the PJRT logits program;
//!   [`server::start_continuous`] runs the same request surface through
//!   the continuous-batching scheduler in [`crate::serving`],
//! - [`metrics`] — counters + streaming histograms (latency, queue wait,
//!   time-to-first-token, step-batch occupancy) + decode traffic for the
//!   above.
//!
//! See `ARCHITECTURE.md` at the repo root for how these fit the crate's
//! overall data flow.

pub mod decode_stream;
pub mod metrics;
pub mod scheduler;
pub mod server;
