//! L3 coordinator: the runtime system around the quantizers.
//!
//! - [`scheduler`] — thread-pool work queue with deterministic reduction
//!   (drives the quantization pipeline),
//! - [`decode_stream`] — the paper's §3.4 on-the-fly decoding: materialize a
//!   handful of sub-blocks, matvec, release (peak-memory bound),
//! - [`server`] — batched LM request loop (generate/score) over the PJRT
//!   logits program with latency/throughput metrics,
//! - [`metrics`] — counters + streaming histograms for the above.

pub mod decode_stream;
pub mod metrics;
pub mod scheduler;
pub mod server;
