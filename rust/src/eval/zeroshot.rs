//! Zero-shot probe tasks — the DESIGN.md §3 substitution for the paper's
//! LM-Eval suite (Table 2):
//!
//! | probe    | stands in for | skill probed                                 |
//! |----------|---------------|----------------------------------------------|
//! | BracketC | ARC-Challenge | long-range type-matched bracket completion    |
//! | BigramE  | ARC-Easy      | frequent-word continuation vs non-word        |
//! | Plaus    | PIQA          | grammatical vs scrambled continuation         |
//! | Induct   | Winogrande    | induction-head entity→verb copying            |
//!
//! Every task is a forced choice scored by the (quantized) LM's total
//! continuation log-probability; accuracy is % correct, exactly the
//! LM-Eval `acc` convention.

use anyhow::Result;

use crate::data::corpus::Vocabulary;
use crate::eval::native_fwd;
use crate::model::ModelConfig;
use crate::runtime::exec::LogitsExec;
use crate::runtime::Engine;
use crate::tensor::TensorStore;
use crate::util::rng::Rng;

/// One forced-choice item.
#[derive(Clone, Debug)]
pub struct ProbeItem {
    pub context: Vec<u8>,
    pub choices: Vec<Vec<u8>>,
    pub correct: usize,
}

/// LM scoring interface: total log P(continuation | prompt).
pub trait LmScorer {
    fn score(&mut self, prompt: &[i32], continuation: &[i32]) -> Result<f64>;
    fn seq_len(&self) -> usize;
}

/// Scorer over the native forward.
pub struct NativeScorer<'a> {
    pub cfg: &'a ModelConfig,
    pub store: &'a TensorStore,
}

impl<'a> LmScorer for NativeScorer<'a> {
    fn score(&mut self, prompt: &[i32], continuation: &[i32]) -> Result<f64> {
        let (x, start) = pad_sequence(prompt, continuation, self.cfg.seq_len);
        let logits = native_fwd::forward(self.cfg, self.store, &x, 1, None)?;
        Ok(continuation_logprob(&logits.data, self.cfg.vocab, &x, start, continuation.len()))
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }
}

/// Scorer over the PJRT logits artifact.
pub struct PjrtScorer {
    exec: LogitsExec,
    params: Vec<crate::runtime::exec::StagedBuf>,
}

impl PjrtScorer {
    pub fn new(engine: &Engine, model: &str, store: &TensorStore) -> Result<PjrtScorer> {
        let exec = LogitsExec::new(engine, model)?;
        let params = exec.stage_params(store)?;
        Ok(PjrtScorer { exec, params })
    }
}

impl LmScorer for PjrtScorer {
    fn score(&mut self, prompt: &[i32], continuation: &[i32]) -> Result<f64> {
        let (x, start) = pad_sequence(prompt, continuation, self.exec.seq);
        let logits = self.exec.logits(&self.params, &x)?;
        Ok(continuation_logprob(&logits, self.exec.vocab, &x, start, continuation.len()))
    }

    fn seq_len(&self) -> usize {
        self.exec.seq
    }
}

/// Left-truncate the prompt so prompt+continuation fits in seq_len; pad
/// right with zeros. Returns (sequence, index of first continuation token).
fn pad_sequence(prompt: &[i32], continuation: &[i32], seq_len: usize) -> (Vec<i32>, usize) {
    let keep = seq_len.saturating_sub(continuation.len()).min(prompt.len());
    let p = &prompt[prompt.len() - keep..];
    let mut x = Vec::with_capacity(seq_len);
    x.extend_from_slice(p);
    let start = x.len();
    x.extend_from_slice(continuation);
    x.resize(seq_len, 0);
    (x, start)
}

/// Sum of `log P(x[t] | x[<t])` for t in `[start, start+len)`. `logits` is the
/// flattened (seq × vocab) array.
fn continuation_logprob(logits: &[f32], vocab: usize, x: &[i32], start: usize, len: usize) -> f64 {
    let mut total = 0.0f64;
    for t in start..start + len {
        // logits at position t-1 predict token t
        let row = &logits[(t - 1) * vocab..t * vocab];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse: f32 = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
        total += (row[x[t] as usize] - lse) as f64;
    }
    total
}

fn scramble_word(w: &str, rng: &mut Rng) -> String {
    let mut b: Vec<u8> = w.bytes().collect();
    rng.shuffle(&mut b);
    // ensure it differs
    if String::from_utf8_lossy(&b) == w {
        b.reverse();
    }
    String::from_utf8_lossy(&b).into_owned()
}

/// Task 1 (ARC-C proxy): long-range bracket completion.
pub fn gen_bracket_items(vocab: &Vocabulary, n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut rng = Rng::new(seed ^ 0xB7AC7);
    (0..n)
        .map(|_| {
            let (open, close, wrong) = if rng.below(2) == 0 {
                ('(', ')', ']')
            } else {
                ('[', ']', ')')
            };
            let inner = format!(
                "{} {} {} {} {}",
                vocab.nouns[rng.below(vocab.nouns.len())],
                vocab.verbs[rng.below(vocab.verbs.len())],
                vocab.nouns[rng.below(vocab.nouns.len())],
                vocab.verbs[rng.below(vocab.verbs.len())],
                vocab.nouns[rng.below(vocab.nouns.len())],
            );
            let ctx = format!(
                "the {} {} the {} {open}{inner}",
                vocab.nouns[rng.below(vocab.nouns.len())],
                vocab.verbs[rng.below(vocab.verbs.len())],
                vocab.nouns[rng.below(vocab.nouns.len())],
            );
            ProbeItem {
                context: ctx.into_bytes(),
                choices: vec![vec![close as u8], vec![wrong as u8]],
                correct: 0,
            }
        })
        .collect()
}

/// Task 2 (ARC-E proxy): real vocabulary word vs scrambled non-word after
/// the frequent "the " bigram.
pub fn gen_bigram_items(vocab: &Vocabulary, n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut rng = Rng::new(seed ^ 0xB16_A);
    (0..n)
        .map(|_| {
            let noun = &vocab.nouns[rng.below(vocab.nouns.len() / 4)]; // frequent nouns
            let wrong = scramble_word(noun, &mut rng);
            let ctx = format!(
                "the {} {} the ",
                vocab.nouns[rng.below(vocab.nouns.len())],
                vocab.verbs[rng.below(vocab.verbs.len())],
            );
            ProbeItem {
                context: ctx.into_bytes(),
                choices: vec![noun.clone().into_bytes(), wrong.into_bytes()],
                correct: 0,
            }
        })
        .collect()
}

/// Task 3 (PIQA proxy): grammatical vs role-violating continuation.
pub fn gen_plaus_items(vocab: &Vocabulary, n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut rng = Rng::new(seed ^ 0x41A);
    (0..n)
        .map(|_| {
            let subj = &vocab.nouns[rng.below(vocab.nouns.len())];
            let verb = &vocab.verbs[rng.below(vocab.verbs.len())];
            let adj = &vocab.adjectives[rng.below(vocab.adjectives.len())];
            let obj = &vocab.nouns[rng.below(vocab.nouns.len())];
            let ctx = format!("the {subj} ");
            // grammatical: verb then object; violation: adjective (never in
            // verb position in the grammar) then object
            let good = format!("{verb} the {obj}.");
            let bad = format!("{adj} the {obj}.");
            ProbeItem {
                context: ctx.into_bytes(),
                choices: vec![good.into_bytes(), bad.into_bytes()],
                correct: 0,
            }
        })
        .collect()
}

/// Task 4 (Winogrande proxy): induction — after "E1 v1 … E2 v2 …", the
/// prompt ends with "E1 " and the model should prefer v1 over v2.
pub fn gen_induction_items(vocab: &Vocabulary, n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut rng = Rng::new(seed ^ 0x14D_0C7);
    (0..n)
        .map(|_| {
            let e1 = &vocab.entities[rng.below(vocab.entities.len())];
            let mut e2 = &vocab.entities[rng.below(vocab.entities.len())];
            while e2 == e1 {
                e2 = &vocab.entities[rng.below(vocab.entities.len())];
            }
            let v1 = &vocab.verbs[rng.below(vocab.verbs.len())];
            let mut v2 = &vocab.verbs[rng.below(vocab.verbs.len())];
            while v2 == v1 {
                v2 = &vocab.verbs[rng.below(vocab.verbs.len())];
            }
            let n1 = &vocab.nouns[rng.below(vocab.nouns.len())];
            let n2 = &vocab.nouns[rng.below(vocab.nouns.len())];
            let ctx = format!("{e1} {v1} the {n1}. {e2} {v2} the {n2}. {e1} ");
            ProbeItem {
                context: ctx.into_bytes(),
                choices: vec![v1.clone().into_bytes(), v2.clone().into_bytes()],
                correct: 0,
            }
        })
        .collect()
}

/// The full probe suite in Table-2 column order.
pub fn task_names() -> [&'static str; 4] {
    ["BracketC", "BigramE", "Plaus", "Induct"]
}

pub fn gen_all_tasks(vocab: &Vocabulary, n: usize, seed: u64) -> Vec<(String, Vec<ProbeItem>)> {
    vec![
        ("BracketC".into(), gen_bracket_items(vocab, n, seed)),
        ("BigramE".into(), gen_bigram_items(vocab, n, seed)),
        ("Plaus".into(), gen_plaus_items(vocab, n, seed)),
        ("Induct".into(), gen_induction_items(vocab, n, seed)),
    ]
}

/// Accuracy of a scorer on a task (% of items whose correct choice wins).
/// Choices are length-normalized (mean per-token logprob) as LM-Eval does
/// for `acc` on unequal-length options.
pub fn eval_task(scorer: &mut dyn LmScorer, items: &[ProbeItem]) -> Result<f64> {
    let mut correct = 0usize;
    for item in items {
        let prompt: Vec<i32> = item.context.iter().map(|&b| b as i32).collect();
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in item.choices.iter().enumerate() {
            let cont: Vec<i32> = choice.iter().map(|&b| b as i32).collect();
            let lp = scorer.score(&prompt, &cont)? / cont.len().max(1) as f64;
            if lp > best.0 {
                best = (lp, ci);
            }
        }
        if best.1 == item.correct {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f64 / items.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Vocabulary;
    use crate::model::{init_params, ModelConfig};

    #[test]
    fn items_are_deterministic_and_well_formed() {
        let vocab = Vocabulary::build(1);
        for (name, items) in gen_all_tasks(&vocab, 20, 7) {
            assert_eq!(items.len(), 20, "{name}");
            let again = match name.as_str() {
                "BracketC" => gen_bracket_items(&vocab, 20, 7),
                "BigramE" => gen_bigram_items(&vocab, 20, 7),
                "Plaus" => gen_plaus_items(&vocab, 20, 7),
                _ => gen_induction_items(&vocab, 20, 7),
            };
            for (a, b) in items.iter().zip(&again) {
                assert_eq!(a.context, b.context);
                assert_eq!(a.choices, b.choices);
            }
            for item in &items {
                assert!(item.correct < item.choices.len());
                assert!(!item.context.is_empty());
                assert!(item.choices.iter().all(|c| !c.is_empty()));
                assert_ne!(item.choices[0], item.choices[1]);
            }
        }
    }

    #[test]
    fn pad_sequence_truncates_left_and_marks_start() {
        let prompt: Vec<i32> = (0..100).collect();
        let cont = vec![200, 201];
        let (x, start) = pad_sequence(&prompt, &cont, 16);
        assert_eq!(x.len(), 16);
        assert_eq!(start, 14);
        assert_eq!(x[13], 99); // last prompt token kept
        assert_eq!(x[14], 200);
    }

    #[test]
    fn random_model_scores_near_chance() {
        let cfg = ModelConfig {
            name: "t",
            vocab: 256,
            d_model: 32,
            n_layer: 1,
            n_head: 2,
            d_ff: 64,
            seq_len: 64,
            batch_train: 2,
            batch_eval: 2,
        };
        let store = init_params(&cfg, 0);
        let vocab = Vocabulary::build(1);
        let items = gen_bracket_items(&vocab, 30, 3);
        let mut scorer = NativeScorer { cfg: &cfg, store: &store };
        let acc = eval_task(&mut scorer, &items).unwrap();
        assert!((10.0..=90.0).contains(&acc), "untrained acc {acc} wildly off chance");
    }
}
