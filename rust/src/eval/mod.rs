//! Evaluation harness: native transformer forward (with calibration
//! capture), perplexity, and the zero-shot probe tasks.
//!
//! The native forward mirrors `python/compile/model.py` operation-for-
//! operation and is cross-checked against the ForwardLoss HLO artifact in
//! rust/tests/pjrt_parity.rs — it exists so (a) per-layer activations can be
//! captured for calibration, (b) evaluation runs even without artifacts,
//! and (c) serving can execute straight from compressed weights: the
//! forward is generic over [`native_fwd::LinearOp`], whose
//! [`native_fwd::StreamedLinear`] implementation drives every quantized
//! linear through the batched streaming decode engine.
//!
//! The forward itself is expressed as a layer plan ([`plan::ModelPlan`]):
//! every variant (full, incremental, ragged) walks the same plan
//! structure and differs only in its attention core — see [`plan::walk`].

pub mod native_fwd;
pub mod plan;
pub mod perplexity;
pub mod zeroshot;
