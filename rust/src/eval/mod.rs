//! Evaluation harness: native transformer forward (with calibration
//! capture), perplexity, and the zero-shot probe tasks.
//!
//! The native forward mirrors `python/compile/model.py` operation-for-
//! operation and is cross-checked against the ForwardLoss HLO artifact in
//! rust/tests/pjrt_parity.rs — it exists so (a) per-layer activations can be
//! captured for calibration and (b) evaluation runs even without artifacts.

pub mod native_fwd;
pub mod perplexity;
pub mod zeroshot;
