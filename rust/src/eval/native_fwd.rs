//! Pure-rust transformer forward — an exact mirror of model.py — plus
//! per-layer activation capture for quantizer calibration.
//!
//! Every forward variant here walks one explicit layer plan
//! ([`crate::eval::plan::ModelPlan`] via [`crate::eval::plan::walk`])
//! instead of a hand-inlined per-variant loop; the variants differ only
//! in the attention core they plug into the walk.
//!
//! The forward is generic over how quantizable linear layers are applied
//! ([`LinearOp`]): [`DenseLinear`] multiplies against dense weights from a
//! [`TensorStore`] (the seed behaviour), while [`StreamedLinear`] runs
//! each linear directly from a compressed [`QuantizedModel`] through the
//! batched [`StreamingMatmul`] engine — the §3.4 serving mode in which no
//! full dequantized layer is ever materialized — and
//! [`crate::shard::ShardedLinear`] spreads it over the tensor-parallel
//! shard executor.
//!
//! [`forward_ragged`] (with its [`forward_incremental`] /
//! [`prefill_with_cache`] / [`step_with_cache`] wrappers) is the
//! KV-cache-aware variant: attention runs only for new positions against
//! cached K/V pages ([`crate::kvcache::PagedKvCache`]), making decode
//! O(T) per token while staying bit-identical to the full recompute on
//! f32 pages. Being *ragged* — each sequence in a call advances by its
//! own token count — it is also the continuous-batching primitive: one
//! step batch can mix a long prompt's prefill chunk with one-token decode
//! steps of unrelated sequences (`serving::ContinuousScheduler`).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use crate::kvcache::{Kv, PagedKvCache, SeqId};
use crate::linalg::Mat;
use crate::model::ModelConfig;
use crate::quant::format::QuantizedModel;
use crate::tensor::TensorStore;
use crate::util::rng::Rng;

/// How a quantizable linear layer `x (rows × n_in) → y (rows × n_out)` is
/// applied. Non-quantizable parameters (embeddings, norm gains) always come
/// from the dense store.
pub trait LinearOp {
    fn apply(&mut self, name: &str, x: &Mat) -> Result<Mat>;
}

/// Dense weights from a [`TensorStore`] — the default path.
pub struct DenseLinear<'a> {
    pub store: &'a TensorStore,
}

impl LinearOp for DenseLinear<'_> {
    fn apply(&mut self, name: &str, x: &Mat) -> Result<Mat> {
        let w = self
            .store
            .get(name)
            .with_context(|| format!("missing {name}"))?
            .to_mat();
        Ok(x.matmul(&w))
    }
}

/// Compressed-weights execution: every quantized tensor is applied through
/// the batched streaming engine (`y = x · Wᵀ_q`, decoded panel by panel);
/// tensors absent from the container fall back to the dense store.
/// `stats` accumulates decode traffic across all layers and calls.
pub struct StreamedLinear<'a> {
    pub qm: &'a QuantizedModel,
    pub store: &'a TensorStore,
    pub engine: &'a StreamingMatmul,
    pub stats: DecodeStats,
}

impl LinearOp for StreamedLinear<'_> {
    fn apply(&mut self, name: &str, x: &Mat) -> Result<Mat> {
        match self.qm.get(name) {
            Some(qt) => {
                let mut y = Mat::zeros(x.rows, qt.rows);
                self.engine.matmul(qt, x, &mut y, &mut self.stats);
                Ok(y)
            }
            None => DenseLinear { store: self.store }.apply(name, x),
        }
    }
}

/// Captures the inputs of each quantizable matmul: tensor name → columns of
/// activations (n_in × up-to-max_cols), subsampled reservoir-style.
pub struct CalibCapture {
    pub max_cols: usize,
    pub cols: BTreeMap<String, Vec<Vec<f32>>>,
    seen: BTreeMap<String, usize>,
    rng: Rng,
}

impl CalibCapture {
    pub fn new(max_cols: usize, seed: u64) -> CalibCapture {
        CalibCapture {
            max_cols,
            cols: BTreeMap::new(),
            seen: BTreeMap::new(),
            rng: Rng::new(seed),
        }
    }

    /// Offer all rows of `acts` (rows = samples, cols = n_in) as candidate
    /// calibration columns for `name` (reservoir sampling keeps a uniform
    /// subsample across the whole eval stream).
    pub(crate) fn offer(&mut self, name: &str, acts: &Mat) {
        let entry = self.cols.entry(name.to_string()).or_default();
        let seen = self.seen.entry(name.to_string()).or_insert(0);
        for r in 0..acts.rows {
            *seen += 1;
            if entry.len() < self.max_cols {
                entry.push(acts.row(r).to_vec());
            } else {
                let j = self.rng.below(*seen);
                if j < self.max_cols {
                    entry[j] = acts.row(r).to_vec();
                }
            }
        }
    }

    /// Finalize into (n_in × N) matrices.
    pub fn into_calib_set(self) -> crate::glvq::pipeline::CalibSet {
        let mut acts = BTreeMap::new();
        for (name, cols) in self.cols {
            if cols.is_empty() {
                continue;
            }
            let n_in = cols[0].len();
            let n = cols.len();
            let mut x = Mat::zeros(n_in, n);
            for (c, col) in cols.iter().enumerate() {
                for (r, &v) in col.iter().enumerate() {
                    *x.at_mut(r, c) = v;
                }
            }
            acts.insert(name, x);
        }
        crate::glvq::pipeline::CalibSet { acts }
    }
}

pub(crate) fn rmsnorm(x: &Mat, gain: &[f32]) -> Mat {
    let mut out = x.clone();
    let d = x.cols;
    for r in 0..x.rows {
        let row = out.row_mut(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = *v * inv * gain[j];
        }
    }
    out
}

pub(crate) fn gelu_tanh(x: f32) -> f32 {
    // jax.nn.gelu(approximate=True)
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place softmax over one row. A fully-masked row (every entry −∞, so
/// every exp underflows to 0 and the naive 0/0 would emit NaN) yields an
/// all-zero row instead: attention treats it as "attend to nothing".
///
/// On any row with at least one finite entry this is bit-identical to the
/// unguarded max-shifted softmax, and applying it to the causal prefix
/// `[0, i]` of a `-1e9`-masked full row gives the same bits as applying
/// it to the whole row: the masked exps underflow to exactly +0.0, which
/// changes neither the max nor the sum. That identity is what lets the
/// incremental KV-cache forward reproduce the full recompute exactly.
fn softmax_slice(row: &mut [f32]) {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    if mx == f32::NEG_INFINITY {
        // empty or fully-masked row
        row.fill(0.0);
        return;
    }
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    if sum == 0.0 {
        row.fill(0.0);
        return;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

fn softmax_rows(m: &mut Mat) {
    for r in 0..m.rows {
        softmax_slice(m.row_mut(r));
    }
}

/// Token + position embedding for a full (B × T) batch: the residual
/// stream the plan walk starts from. Shared by [`forward_with`] and the
/// pipeline executor (`cluster::PipelineExec`), which must start from the
/// exact same bits.
pub(crate) fn embed_full(
    cfg: &ModelConfig,
    store: &TensorStore,
    tokens: &[i32],
    batch: usize,
) -> Result<Mat> {
    let (t_len, d) = (cfg.seq_len, cfg.d_model);
    assert_eq!(tokens.len(), batch * t_len);
    let emb = store.get("emb").context("missing emb")?.to_mat();
    let pos = store.get("pos").context("missing pos")?.to_mat();
    let mut h = Mat::zeros(batch * t_len, d);
    for b in 0..batch {
        for t in 0..t_len {
            let tok = tokens[b * t_len + t] as usize;
            let dst = h.row_mut(b * t_len + t);
            for j in 0..d {
                dst[j] = emb.at(tok, j) + pos.at(t, j);
            }
        }
    }
    Ok(h)
}

/// The dense causal attention core over an in-call (B × T) batch — the
/// attend closure of [`forward_with`], extracted so pipeline stage workers
/// run the identical code. Every sequence (T-row block) is independent, so
/// splitting a batch across calls reproduces the same bits row for row.
pub(crate) fn attend_dense(cfg: &ModelConfig, batch: usize, q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let (t_len, d) = (cfg.seq_len, cfg.d_model);
    let (nh, dh) = (cfg.n_head, cfg.d_head());
    let scale = 1.0 / (dh as f32).sqrt();
    let mut att_out = Mat::zeros(batch * t_len, d);
    for b in 0..batch {
        for head in 0..nh {
            let off = head * dh;
            // scores (T × T) for this batch/head
            let mut scores = Mat::zeros(t_len, t_len);
            for i in 0..t_len {
                let qi = &q.row(b * t_len + i)[off..off + dh];
                for j in 0..=i {
                    let kj = &k.row(b * t_len + j)[off..off + dh];
                    let mut s = 0.0f32;
                    for e in 0..dh {
                        s += qi[e] * kj[e];
                    }
                    *scores.at_mut(i, j) = s * scale;
                }
                for j in i + 1..t_len {
                    *scores.at_mut(i, j) = -1e9;
                }
            }
            softmax_rows(&mut scores);
            for i in 0..t_len {
                let dst = &mut att_out.row_mut(b * t_len + i)[off..off + dh];
                for j in 0..=i {
                    let w = scores.at(i, j);
                    if w == 0.0 {
                        continue;
                    }
                    let vj = &v.row(b * t_len + j)[off..off + dh];
                    for e in 0..dh {
                        dst[e] += w * vj[e];
                    }
                }
            }
        }
    }
    att_out
}

/// Forward pass over one (B × T) token batch with dense weights. Returns
/// logits (B·T × V). If `capture` is set, quantizable-matmul inputs are
/// offered to it.
pub fn forward(
    cfg: &ModelConfig,
    store: &TensorStore,
    tokens: &[i32],
    batch: usize,
    capture: Option<&mut CalibCapture>,
) -> Result<Mat> {
    let mut lin = DenseLinear { store };
    forward_with(cfg, store, &mut lin, tokens, batch, capture)
}

/// Forward pass with an explicit [`LinearOp`] for the quantizable linears
/// (dense or streamed-from-compressed); embeddings and norm gains always
/// read from `store`.
///
/// Implemented as a [`crate::eval::plan::ModelPlan`] walk whose attention
/// core computes dense causal scores over the in-call (B × T) batch — the
/// same plan structure the incremental/ragged forwards walk.
pub fn forward_with(
    cfg: &ModelConfig,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    tokens: &[i32],
    batch: usize,
    capture: Option<&mut CalibCapture>,
) -> Result<Mat> {
    let mut h = embed_full(cfg, store, tokens, batch)?;
    let model_plan = crate::eval::plan::ModelPlan::of(cfg);
    crate::eval::plan::walk(&model_plan, store, lin, &mut h, capture, |_, q, k, v| {
        Ok(attend_dense(cfg, batch, q, k, v))
    })
}

/// Cache-aware incremental forward: append `tokens.len() / seqs.len()`
/// new tokens per sequence to the paged KV cache and return logits for
/// exactly the new positions (`seqs.len()·n_new × V`, sequence-major).
///
/// Attention for a new position computes scores only against that
/// sequence's cached K/V prefix (including the rows appended this call),
/// so a one-token step costs O(T) instead of the O(T²) full recompute.
/// With f32 cache pages the logits are **bit-identical** to
/// [`forward_with`] over the same prefix (tested here and in
/// `tests/kvcache_parity.rs`): every per-row op (rmsnorm, the blocked
/// matmul, the causal softmax, the j-ascending V accumulation) is
/// row-count-independent, and `softmax_slice` over the causal prefix
/// equals the masked full-row softmax exactly. Quantized cache pages
/// trade that for bounded reconstruction error (documented NLL tolerance
/// in the parity test).
///
/// `tokens` is flat `(seqs.len() × n_new)`, row-major; every sequence
/// advances by the same `n_new` (prefill calls pass one sequence with the
/// whole prompt, lockstep decode passes many sequences with one token
/// each). Errors if any sequence would exceed `cfg.seq_len` positions.
/// Thin wrapper over [`forward_ragged`], which additionally allows a
/// different token count per sequence.
pub fn forward_incremental(
    cfg: &ModelConfig,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    cache: &mut PagedKvCache,
    seqs: &[SeqId],
    tokens: &[i32],
) -> Result<Mat> {
    let batch = seqs.len();
    anyhow::ensure!(batch > 0 && !tokens.is_empty(), "empty incremental batch");
    anyhow::ensure!(tokens.len() % batch == 0, "tokens not divisible into {batch} sequences");
    let n_new = tokens.len() / batch;
    let slices: Vec<&[i32]> = tokens.chunks_exact(n_new).collect();
    forward_ragged(cfg, store, lin, cache, seqs, &slices)
}

/// Variable-membership cache-aware forward — the continuous-batching
/// primitive: each sequence in `seqs` advances by its **own** number of
/// new tokens (`tokens[b]`, non-empty), so one call can mix a prefill
/// chunk of one request with one-token decode steps of others. Returns
/// logits for exactly the new positions, sequence-major
/// (`Σ tokens[b].len() × V`; sequence `b`'s rows start at
/// `Σ_{b'<b} tokens[b'].len()`).
///
/// Every per-row operation (rmsnorm, the linears, the causal softmax over
/// each row's own prefix, the j-ascending V accumulation) is independent
/// of which other rows share the call, so the logits are **bit-identical**
/// to any other chunking of the same token streams — one big prefill, a
/// chain of one-token steps, or any ragged mix (tested below and in
/// `tests/continuous_parity.rs`). Errors if any sequence would exceed
/// `cfg.seq_len` positions.
pub fn forward_ragged(
    cfg: &ModelConfig,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    cache: &mut PagedKvCache,
    seqs: &[SeqId],
    tokens: &[&[i32]],
) -> Result<Mat> {
    let _sp = crate::span!("forward_ragged");
    let batch = seqs.len();
    anyhow::ensure!(batch > 0, "empty ragged batch");
    anyhow::ensure!(tokens.len() == batch, "one token slice per sequence");
    anyhow::ensure!(
        tokens.iter().all(|t| !t.is_empty()),
        "every sequence must advance by at least one token"
    );
    let counts: Vec<usize> = tokens.iter().map(|t| t.len()).collect();
    // row offset of each sequence's first new position in the flat output
    let mut offs = Vec::with_capacity(batch);
    let mut total = 0usize;
    for &c in &counts {
        offs.push(total);
        total += c;
    }
    let d = cfg.d_model;

    // cache length of each sequence before this call = the absolute
    // position of its first new token
    let bases: Vec<usize> = seqs.iter().map(|&s| cache.rows(s, 0, Kv::K)).collect();
    for (b, &base) in bases.iter().enumerate() {
        anyhow::ensure!(
            base + counts[b] <= cfg.seq_len,
            "sequence {b} exceeds seq_len {} ({base} cached + {} new)",
            cfg.seq_len,
            counts[b]
        );
    }

    let emb = store.get("emb").context("missing emb")?.to_mat();
    let pos = store.get("pos").context("missing pos")?.to_mat();
    let mut h = Mat::zeros(total, d);
    for b in 0..batch {
        for r in 0..counts[b] {
            let tok = tokens[b][r] as usize;
            let p = bases[b] + r;
            let dst = h.row_mut(offs[b] + r);
            for j in 0..d {
                dst[j] = emb.at(tok, j) + pos.at(p, j);
            }
        }
    }

    let (nh, dh) = (cfg.n_head, cfg.d_head());
    let scale = 1.0 / (dh as f32).sqrt();

    // same plan structure as the full forward; only the attention core
    // differs: new rows only, K/V prefix read back from the cache
    let model_plan = crate::eval::plan::ModelPlan::of(cfg);
    crate::eval::plan::walk(&model_plan, store, lin, &mut h, None, |lp, q, k, v| {
        let layer = lp.index;
        for (b, &sid) in seqs.iter().enumerate() {
            for r in 0..counts[b] {
                cache.append(sid, layer, Kv::K, k.row(offs[b] + r))?;
                cache.append(sid, layer, Kv::V, v.row(offs[b] + r))?;
            }
        }
        let mut att_out = Mat::zeros(total, d);
        for (b, &sid) in seqs.iter().enumerate() {
            let base = bases[b];
            let n_new = counts[b];
            let row0 = offs[b];
            let l_total = base + n_new;
            // scores[(head·n_new + r)·l_total + j], causal: j ≤ base + r
            let mut scores = vec![0.0f32; nh * n_new * l_total];
            cache.visit(sid, layer, Kv::K, l_total, |pos0, kr| {
                for (rr, krow) in kr.chunks_exact(d).enumerate() {
                    let j = pos0 + rr;
                    for head in 0..nh {
                        let off = head * dh;
                        let kh = &krow[off..off + dh];
                        for r in 0..n_new {
                            if j > base + r {
                                continue;
                            }
                            let qh = &q.row(row0 + r)[off..off + dh];
                            let mut s = 0.0f32;
                            for e in 0..dh {
                                s += qh[e] * kh[e];
                            }
                            scores[(head * n_new + r) * l_total + j] = s * scale;
                        }
                    }
                }
            });
            for head in 0..nh {
                for r in 0..n_new {
                    let srow0 = (head * n_new + r) * l_total;
                    softmax_slice(&mut scores[srow0..srow0 + base + r + 1]);
                }
            }
            cache.visit(sid, layer, Kv::V, l_total, |pos0, vr| {
                for (rr, vrow) in vr.chunks_exact(d).enumerate() {
                    let j = pos0 + rr;
                    for head in 0..nh {
                        let off = head * dh;
                        let vh = &vrow[off..off + dh];
                        for r in 0..n_new {
                            if j > base + r {
                                continue;
                            }
                            let w = scores[(head * n_new + r) * l_total + j];
                            if w == 0.0 {
                                continue;
                            }
                            let dst = &mut att_out.row_mut(row0 + r)[off..off + dh];
                            for e in 0..dh {
                                dst[e] += w * vh[e];
                            }
                        }
                    }
                }
            });
        }
        Ok(att_out)
    })
}

/// Prefill one sequence's prompt into the cache; returns logits for every
/// prompt position (`tokens.len() × V`). Convenience wrapper over
/// [`forward_incremental`].
pub fn prefill_with_cache(
    cfg: &ModelConfig,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    cache: &mut PagedKvCache,
    seq: SeqId,
    tokens: &[i32],
) -> Result<Mat> {
    forward_incremental(cfg, store, lin, cache, std::slice::from_ref(&seq), tokens)
}

/// Advance every sequence by one token in lockstep; returns last-position
/// logits per sequence (`seqs.len() × V`). Convenience wrapper over
/// [`forward_incremental`].
pub fn step_with_cache(
    cfg: &ModelConfig,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    cache: &mut PagedKvCache,
    seqs: &[SeqId],
    last_tokens: &[i32],
) -> Result<Mat> {
    assert_eq!(seqs.len(), last_tokens.len(), "one new token per sequence");
    forward_incremental(cfg, store, lin, cache, seqs, last_tokens)
}

/// Total NLL over a batch (matches model.py::nll_sum).
pub fn nll_sum(
    cfg: &ModelConfig,
    store: &TensorStore,
    x: &[i32],
    y: &[i32],
    batch: usize,
) -> Result<f64> {
    let logits = forward(cfg, store, x, batch, None)?;
    Ok(nll_from_logits(&logits, y))
}

/// Index of the largest logit (greedy decode), ties resolved to the last
/// maximal index — the one sampling rule shared by the server's lockstep
/// loop and every bench/example/test generation driver. Panics on NaN
/// logits; returns 0 for an empty row.
pub fn argmax_logit(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// NLL from precomputed logits (rows = positions, cols = vocab).
pub fn nll_from_logits(logits: &Mat, targets: &[i32]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0.0f64;
    for r in 0..logits.rows {
        let row = logits.row(r);
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse: f32 = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
        total += (lse - row[targets[r] as usize]) as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ModelConfig, CONFIG_S};

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t",
            vocab: 256,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
            seq_len: 16,
            batch_train: 2,
            batch_eval: 2,
        }
    }

    fn toks(cfg: &ModelConfig, batch: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..batch * cfg.seq_len).map(|_| rng.below(256) as i32).collect()
    }

    #[test]
    fn forward_shapes_and_finite() {
        let cfg = tiny();
        let store = init_params(&cfg, 0);
        let x = toks(&cfg, 2, 1);
        let logits = forward(&cfg, &store, &x, 2, None).unwrap();
        assert_eq!((logits.rows, logits.cols), (2 * 16, 256));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn initial_loss_near_uniform() {
        let cfg = tiny();
        let store = init_params(&cfg, 0);
        let x = toks(&cfg, 2, 2);
        let y = toks(&cfg, 2, 3);
        let nll = nll_sum(&cfg, &store, &x, &y, 2).unwrap();
        let per_tok = nll / (2.0 * 16.0);
        assert!((per_tok - (256f64).ln()).abs() < 0.5, "per-token nll {per_tok}");
    }

    #[test]
    fn causality() {
        let cfg = tiny();
        let store = init_params(&cfg, 1);
        let mut x1 = toks(&cfg, 1, 4);
        let logits1 = forward(&cfg, &store, &x1, 1, None).unwrap();
        // perturb the future
        for t in 10..16 {
            x1[t] = (x1[t] + 37) % 256;
        }
        let logits2 = forward(&cfg, &store, &x1, 1, None).unwrap();
        for t in 0..10 {
            for v in 0..256 {
                assert!(
                    (logits1.at(t, v) - logits2.at(t, v)).abs() < 1e-4,
                    "position {t} affected by future"
                );
            }
        }
        let mut diff = 0.0f32;
        for t in 10..16 {
            for v in 0..256 {
                diff += (logits1.at(t, v) - logits2.at(t, v)).abs();
            }
        }
        assert!(diff > 1.0, "future positions should change");
    }

    #[test]
    fn capture_collects_all_quantizable_inputs() {
        let cfg = tiny();
        let store = init_params(&cfg, 2);
        let x = toks(&cfg, 2, 5);
        let mut cap = CalibCapture::new(24, 0);
        forward(&cfg, &store, &x, 2, Some(&mut cap)).unwrap();
        let calib = cap.into_calib_set();
        for name in cfg.quantizable_names() {
            let xm = calib.acts.get(&name).unwrap_or_else(|| panic!("missing {name}"));
            let spec = cfg
                .param_specs()
                .into_iter()
                .find(|s| s.name == name)
                .unwrap();
            assert_eq!(xm.rows, spec.shape[0], "{name}");
            assert_eq!(xm.cols, 24.min(2 * 16), "{name}");
            assert!(xm.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn capture_reservoir_caps_columns() {
        let cfg = tiny();
        let store = init_params(&cfg, 3);
        let mut cap = CalibCapture::new(8, 1);
        for seed in 0..3 {
            let x = toks(&cfg, 2, 100 + seed);
            forward(&cfg, &store, &x, 2, Some(&mut cap)).unwrap();
        }
        let calib = cap.into_calib_set();
        for (_, x) in calib.acts {
            assert_eq!(x.cols, 8);
        }
    }

    #[test]
    fn streamed_forward_matches_dense_dequantized_forward() {
        // the compressed-weights serving mode must produce the same logits
        // as running dense over the dequantized store — without ever
        // materializing more than one panel of decoded weights
        let cfg = tiny();
        let store = init_params(&cfg, 7);
        let x = toks(&cfg, 2, 21);
        let mut cap = CalibCapture::new(16, 0);
        forward(&cfg, &store, &x, 2, Some(&mut cap)).unwrap();
        let calib = cap.into_calib_set();
        let mut opts = crate::glvq::pipeline::PipelineOpts::default();
        opts.target_bits = 3.0;
        opts.bit_allocation = false;
        let (qm, _) = crate::glvq::pipeline::quantize_model(
            &cfg.param_specs(),
            &store,
            &calib,
            &crate::baselines::rtn::RtnQuantizer,
            &opts,
        )
        .unwrap();

        let dq = crate::glvq::pipeline::dequantized_store(&qm, &store);
        let want = forward(&cfg, &dq, &x, 2, None).unwrap();

        let engine = StreamingMatmul::new(8, 2);
        let mut lin = StreamedLinear {
            qm: &qm,
            store: &store,
            engine: &engine,
            stats: DecodeStats::default(),
        };
        let got = forward_with(&cfg, &store, &mut lin, &x, 2, None).unwrap();
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }

        // the fused decode-GEMM mode (engine default = Auto) and the
        // classic slab mode must produce bit-identical logits end to end
        let slab_engine = StreamingMatmul::new(8, 2).with_mode(crate::kernels::ExecMode::Slab);
        let mut slab_lin = StreamedLinear {
            qm: &qm,
            store: &store,
            engine: &slab_engine,
            stats: DecodeStats::default(),
        };
        let got_slab = forward_with(&cfg, &store, &mut slab_lin, &x, 2, None).unwrap();
        assert_eq!(got.data, got_slab.data, "fused vs slab logits not bit-identical");
        // §3.4 bound: peak decoded working set ≤ one panel (panel_rows ×
        // n_in), far below any full dequantized layer
        let max_n_in = cfg.d_model.max(cfg.d_ff);
        assert!(lin.stats.peak_decoded > 0 && lin.stats.code_bytes > 0);
        assert!(lin.stats.peak_decoded <= engine.panel_rows * max_n_in);
        let smallest_layer = cfg.d_model * cfg.d_model;
        assert!(
            lin.stats.peak_decoded < smallest_layer,
            "streamed forward materialized a full layer ({} elems)",
            lin.stats.peak_decoded
        );
    }

    #[test]
    fn config_s_runs() {
        let cfg = CONFIG_S;
        let store = init_params(&cfg, 4);
        let mut rng = Rng::new(9);
        let x: Vec<i32> = (0..cfg.seq_len).map(|_| rng.below(256) as i32).collect();
        let logits = forward(&cfg, &store, &x, 1, None).unwrap();
        assert_eq!(logits.rows, cfg.seq_len);
    }

    #[test]
    fn softmax_guards_fully_masked_rows() {
        let mut m = Mat::from_vec(
            2,
            3,
            vec![f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY, 0.0, 1.0, 2.0],
        );
        softmax_rows(&mut m);
        assert_eq!(m.row(0), &[0.0, 0.0, 0.0], "masked row must be zeros, not NaN");
        let s: f32 = m.row(1).iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(m.data.iter().all(|v| !v.is_nan()));
        // the -1e9 causal-mask convention still softmaxes normally
        let mut c = Mat::from_vec(1, 3, vec![0.5, -1e9, -1e9]);
        softmax_rows(&mut c);
        assert_eq!(c.row(0), &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn softmax_prefix_equals_masked_full_row() {
        // the identity the incremental forward relies on: softmax over the
        // causal prefix == softmax over the -1e9-masked full row, bitwise
        let mut rng = Rng::new(7);
        for len in [1usize, 3, 7] {
            let vals: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let mut full = vals.clone();
            full.resize(10, -1e9);
            softmax_slice(&mut full);
            let mut prefix = vals;
            softmax_slice(&mut prefix);
            assert_eq!(&full[..len], &prefix[..]);
            assert!(full[len..].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn incremental_forward_is_bit_identical_to_full_recompute() {
        let cfg = tiny();
        let store = init_params(&cfg, 5);
        let mut rng = Rng::new(31);
        let prompt: Vec<i32> = (0..10).map(|_| rng.below(256) as i32).collect();

        let opts = crate::kvcache::KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut cache = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
        let sid = cache.new_seq();
        let mut lin = DenseLinear { store: &store };
        let pre = prefill_with_cache(&cfg, &store, &mut lin, &mut cache, sid, &prompt).unwrap();
        assert_eq!((pre.rows, pre.cols), (10, cfg.vocab));

        // full recompute over the padded prompt: rows 0..10 match bitwise
        let mut padded = prompt.clone();
        padded.resize(cfg.seq_len, 0);
        let full = forward(&cfg, &store, &padded, 1, None).unwrap();
        for t in 0..10 {
            assert_eq!(pre.row(t), full.row(t), "prefill row {t} diverged");
        }

        // decode steps up to seq_len: each must equal the full recompute
        let mut toks = prompt.clone();
        while toks.len() < cfg.seq_len {
            let next = rng.below(256) as i32;
            let mut lin = DenseLinear { store: &store };
            let step =
                step_with_cache(&cfg, &store, &mut lin, &mut cache, &[sid], &[next]).unwrap();
            toks.push(next);
            let mut padded = toks.clone();
            padded.resize(cfg.seq_len, 0);
            let full = forward(&cfg, &store, &padded, 1, None).unwrap();
            assert_eq!(
                step.row(0),
                full.row(toks.len() - 1),
                "step at position {} diverged",
                toks.len() - 1
            );
        }
        // capacity is enforced once the model's position table runs out
        let mut lin = DenseLinear { store: &store };
        assert!(step_with_cache(&cfg, &store, &mut lin, &mut cache, &[sid], &[1]).is_err());
    }

    #[test]
    fn batched_steps_match_per_sequence_steps() {
        // lockstep batch-of-B one-token steps must equal stepping each
        // sequence alone (per-row op independence)
        let cfg = tiny();
        let store = init_params(&cfg, 8);
        let mut rng = Rng::new(41);
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|i| (0..(4 + 3 * i)).map(|_| rng.below(256) as i32).collect())
            .collect();
        let opts = crate::kvcache::KvCacheOpts { page_rows: 4, ..Default::default() };

        let mut cb = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
        let ids: Vec<_> = prompts
            .iter()
            .map(|p| {
                let sid = cb.new_seq();
                let mut lin = DenseLinear { store: &store };
                prefill_with_cache(&cfg, &store, &mut lin, &mut cb, sid, p).unwrap();
                sid
            })
            .collect();
        let next = [7i32, 11, 13];
        let mut lin = DenseLinear { store: &store };
        let batched = step_with_cache(&cfg, &store, &mut lin, &mut cb, &ids, &next).unwrap();

        for (i, p) in prompts.iter().enumerate() {
            let mut cs = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
            let sid = cs.new_seq();
            let mut lin = DenseLinear { store: &store };
            prefill_with_cache(&cfg, &store, &mut lin, &mut cs, sid, p).unwrap();
            let solo =
                step_with_cache(&cfg, &store, &mut lin, &mut cs, &[sid], &[next[i]]).unwrap();
            assert_eq!(batched.row(i), solo.row(0), "sequence {i} diverged in batch");
        }
    }

    #[test]
    fn ragged_chunked_prefill_is_bit_identical_to_one_shot_prefill() {
        // feeding a prompt in uneven chunks must reproduce the one-shot
        // prefill logits bitwise at every position — the property chunked
        // prefill rests on
        let cfg = tiny();
        let store = init_params(&cfg, 12);
        let mut rng = Rng::new(77);
        let prompt: Vec<i32> = (0..13).map(|_| rng.below(256) as i32).collect();
        let opts = crate::kvcache::KvCacheOpts { page_rows: 4, ..Default::default() };

        let mut c1 = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
        let s1 = c1.new_seq();
        let mut lin = DenseLinear { store: &store };
        let want = prefill_with_cache(&cfg, &store, &mut lin, &mut c1, s1, &prompt).unwrap();

        let mut c2 = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
        let s2 = c2.new_seq();
        let mut got_rows: Vec<Vec<f32>> = Vec::new();
        let mut fed = 0usize;
        for take in [3usize, 1, 5, 4] {
            let chunk = &prompt[fed..fed + take];
            let mut lin = DenseLinear { store: &store };
            let part = forward_ragged(&cfg, &store, &mut lin, &mut c2, &[s2], &[chunk]).unwrap();
            assert_eq!(part.rows, take);
            for r in 0..take {
                got_rows.push(part.row(r).to_vec());
            }
            fed += take;
        }
        assert_eq!(fed, prompt.len());
        for (t, row) in got_rows.iter().enumerate() {
            assert_eq!(row.as_slice(), want.row(t), "chunked prefill diverged at position {t}");
        }
    }

    #[test]
    fn ragged_mixed_chunk_and_decode_matches_separate_calls() {
        // one ragged call carrying {a prefill chunk, two one-token decode
        // steps} must equal running each sequence in its own call — the
        // variable-membership step batch is exactly as exact as lockstep
        let cfg = tiny();
        let store = init_params(&cfg, 13);
        let mut rng = Rng::new(88);
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|i| (0..(3 + 2 * i)).map(|_| rng.below(256) as i32).collect())
            .collect();
        let chunk: Vec<i32> = (0..6).map(|_| rng.below(256) as i32).collect();
        let opts = crate::kvcache::KvCacheOpts { page_rows: 4, ..Default::default() };

        // reference: every sequence advanced in its own call
        let mut cs = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
        let ids: Vec<_> = prompts
            .iter()
            .map(|p| {
                let sid = cs.new_seq();
                let mut lin = DenseLinear { store: &store };
                prefill_with_cache(&cfg, &store, &mut lin, &mut cs, sid, p).unwrap();
                sid
            })
            .collect();
        let mut lin = DenseLinear { store: &store };
        let solo0 =
            forward_ragged(&cfg, &store, &mut lin, &mut cs, &[ids[0]], &[&chunk]).unwrap();
        let mut lin = DenseLinear { store: &store };
        let solo1 = forward_ragged(&cfg, &store, &mut lin, &mut cs, &[ids[1]], &[&[7][..]])
            .unwrap();
        let mut lin = DenseLinear { store: &store };
        let solo2 = forward_ragged(&cfg, &store, &mut lin, &mut cs, &[ids[2]], &[&[11][..]])
            .unwrap();

        // one fused variable-membership batch over fresh caches
        let mut cb = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
        let idb: Vec<_> = prompts
            .iter()
            .map(|p| {
                let sid = cb.new_seq();
                let mut lin = DenseLinear { store: &store };
                prefill_with_cache(&cfg, &store, &mut lin, &mut cb, sid, p).unwrap();
                sid
            })
            .collect();
        let mut lin = DenseLinear { store: &store };
        let toks: Vec<&[i32]> = vec![&chunk[..], &[7][..], &[11][..]];
        let fused = forward_ragged(&cfg, &store, &mut lin, &mut cb, &idb, &toks).unwrap();
        assert_eq!(fused.rows, chunk.len() + 2);
        for r in 0..chunk.len() {
            assert_eq!(fused.row(r), solo0.row(r), "chunk row {r} diverged in fused batch");
        }
        assert_eq!(fused.row(chunk.len()), solo1.row(0), "decode step 1 diverged");
        assert_eq!(fused.row(chunk.len() + 1), solo2.row(0), "decode step 2 diverged");
    }

    #[test]
    fn ragged_rejects_malformed_batches() {
        let cfg = tiny();
        let store = init_params(&cfg, 14);
        let opts = crate::kvcache::KvCacheOpts { page_rows: 4, ..Default::default() };
        let mut c = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
        let s = c.new_seq();
        let mut lin = DenseLinear { store: &store };
        let empty: &[i32] = &[];
        assert!(forward_ragged(&cfg, &store, &mut lin, &mut c, &[s], &[empty]).is_err());
        assert!(forward_ragged(&cfg, &store, &mut lin, &mut c, &[], &[]).is_err());
        assert!(forward_ragged(&cfg, &store, &mut lin, &mut c, &[s], &[]).is_err());
    }

    #[test]
    fn quantized_kv_stays_close_to_f32_kv() {
        let cfg = tiny();
        let store = init_params(&cfg, 9);
        let mut rng = Rng::new(51);
        let prompt: Vec<i32> = (0..12).map(|_| rng.below(256) as i32).collect();
        let run = |opts: crate::kvcache::KvCacheOpts| {
            let mut cache = crate::kvcache::PagedKvCache::new(cfg.n_layer, cfg.d_model, opts);
            let sid = cache.new_seq();
            let mut lin = DenseLinear { store: &store };
            let l = prefill_with_cache(&cfg, &store, &mut lin, &mut cache, sid, &prompt).unwrap();
            (l, cache.stats())
        };
        let (f32_logits, f32_stats) = run(crate::kvcache::KvCacheOpts {
            page_rows: 4,
            ..Default::default()
        });
        let (q_logits, q_stats) = run(crate::kvcache::KvCacheOpts {
            page_rows: 4,
            quantize: true,
            kv_bits: 8,
            ..Default::default()
        });
        assert_eq!(f32_stats.pages_quantized, 0);
        assert!(q_stats.pages_quantized > 0, "quantized run must retire pages");
        assert!(q_stats.decoded_bytes > 0);
        let last = f32_logits.rows - 1;
        for (a, b) in q_logits.row(last).iter().zip(f32_logits.row(last)) {
            assert!((a - b).abs() < 0.25, "8-bit KV drifted logits: {a} vs {b}");
        }
    }
}
