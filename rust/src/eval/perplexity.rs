//! Perplexity evaluation: exp(mean NLL) over a held-out token stream, via
//! either the PJRT ForwardLoss artifact (production path) or the native
//! forward (artifact-free path). Both are cross-checked in integration
//! tests.

use anyhow::Result;

use crate::data::batches::BatchIter;
use crate::eval::native_fwd;
use crate::model::ModelConfig;
use crate::runtime::exec::ForwardLossExec;
use crate::runtime::Engine;
use crate::tensor::TensorStore;

/// Perplexity result with token accounting.
#[derive(Clone, Copy, Debug)]
pub struct PplResult {
    pub ppl: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
}

/// Evaluate perplexity with the native forward.
pub fn ppl_native(
    cfg: &ModelConfig,
    store: &TensorStore,
    tokens: &[i32],
    max_batches: usize,
) -> Result<PplResult> {
    let batch = cfg.batch_eval;
    let mut it = BatchIter::new(tokens, batch, cfg.seq_len, 0, false);
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    let mut batches = 0usize;
    while let Some((x, y)) = it.next_batch() {
        total_nll += native_fwd::nll_sum(cfg, store, &x, &y, batch)?;
        total_tokens += x.len();
        batches += 1;
        if batches >= max_batches {
            break;
        }
    }
    finish(total_nll, total_tokens)
}

/// Evaluate perplexity through the PJRT ForwardLoss artifact.
pub fn ppl_pjrt(
    engine: &Engine,
    model: &str,
    store: &TensorStore,
    tokens: &[i32],
    max_batches: usize,
) -> Result<PplResult> {
    let exec = ForwardLossExec::new(engine, model)?;
    let params = exec.stage_params(store)?;
    let mut it = BatchIter::new(tokens, exec.batch, exec.seq, 0, false);
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    let mut batches = 0usize;
    while let Some((x, y)) = it.next_batch() {
        total_nll += exec.nll_sum(&params, &x, &y)?;
        total_tokens += x.len();
        batches += 1;
        if batches >= max_batches {
            break;
        }
    }
    finish(total_nll, total_tokens)
}

fn finish(total_nll: f64, total_tokens: usize) -> Result<PplResult> {
    anyhow::ensure!(total_tokens > 0, "no tokens evaluated");
    let nll_per_token = total_nll / total_tokens as f64;
    Ok(PplResult { ppl: nll_per_token.exp(), nll_per_token, tokens: total_tokens })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, Mix};
    use crate::data::tokenizer::encode;
    use crate::model::{init_params, ModelConfig};

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t",
            vocab: 256,
            d_model: 32,
            n_layer: 1,
            n_head: 2,
            d_ff: 64,
            seq_len: 32,
            batch_train: 2,
            batch_eval: 2,
        }
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        let cfg = tiny();
        let store = init_params(&cfg, 0);
        let text = Corpus::new(Mix::Wiki, 1).generate(4096);
        let tokens = encode(&text);
        let r = ppl_native(&cfg, &store, &tokens, 4).unwrap();
        // untrained model ≈ uniform over 256 tokens
        assert!(r.ppl > 100.0 && r.ppl < 600.0, "ppl={}", r.ppl);
        assert_eq!(r.tokens, 4 * 2 * 32);
    }

    #[test]
    fn degraded_weights_increase_ppl() {
        let cfg = tiny();
        let store = init_params(&cfg, 1);
        let text = Corpus::new(Mix::Wiki, 2).generate(4096);
        let tokens = encode(&text);
        let base = ppl_native(&cfg, &store, &tokens, 2).unwrap();
        // zero out a projection: ppl should move (weights matter)
        let mut broken = store.clone();
        let mut t = broken.get("out").unwrap().clone();
        for v in t.data.iter_mut() {
            *v = 0.0;
        }
        broken.insert("out", t);
        let b = ppl_native(&cfg, &broken, &tokens, 2).unwrap();
        assert!((b.ppl - 256.0).abs() < 1.0, "zero head ⇒ exactly uniform, got {}", b.ppl);
        assert!(base.ppl != b.ppl);
    }
}
