//! Layer-plan execution: the transformer forward as an explicit plan of
//! [`LinearOp`] nodes instead of a hand-inlined loop.
//!
//! A [`ModelPlan`] is the static execution graph of one model: for every
//! transformer layer, a [`LayerPlan`] naming the norm gains and the seven
//! quantizable linears (`wq`/`wk`/`wv`/`wo`, `w1`/`w2`, and the shared
//! `out` head at the end). [`walk`] is the single interpreter of that
//! structure: it runs rmsnorm → q/k/v linears → *attend* → output
//! projection → residual → mlp for every layer, in exactly the operation
//! order the hand-written forwards used, so the refactor is bit-identical
//! to the pre-plan code (asserted by the existing `native_fwd` parity
//! tests).
//!
//! What varies between the full forward ([`super::native_fwd::forward_with`])
//! and the cache-aware ragged forward
//! ([`super::native_fwd::forward_ragged`]) is **only the attention core**
//! — dense causal scores over the in-call batch vs. scores against cached
//! K/V pages — so `walk` takes it as a closure over the freshly computed
//! `(q, k, v)` activations. Everything else (which linears run, in which
//! order, where calibration capture hooks, where residuals add) lives in
//! one place.
//!
//! The plan is also the sharding unit: `shard::ShardPlan` partitions the
//! `QuantizedTensor` behind every linear node along its group boundaries,
//! and the plan walk stays unchanged — only the [`LinearOp`] behind
//! `apply` switches from single-engine streaming to the sharded executor.

use anyhow::{Context, Result};

use crate::eval::native_fwd::{gelu_tanh, rmsnorm, CalibCapture, LinearOp};
use crate::linalg::Mat;
use crate::model::ModelConfig;
use crate::tensor::TensorStore;

/// One transformer layer's node names: the two norm gains plus the six
/// quantizable linears, in execution order.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    /// layer index (0-based)
    pub index: usize,
    pub attn_gain: String,
    pub wq: String,
    pub wk: String,
    pub wv: String,
    pub wo: String,
    pub mlp_gain: String,
    pub w1: String,
    pub w2: String,
}

/// The whole model as a plan: per-layer nodes plus the final norm and the
/// output head.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelPlan {
    pub layers: Vec<LayerPlan>,
    pub final_gain: String,
    pub out: String,
}

impl ModelPlan {
    /// Build the plan for a model configuration. Node names match
    /// [`ModelConfig::param_specs`] exactly (tested below), so the same
    /// plan addresses dense stores and quantized containers.
    pub fn of(cfg: &ModelConfig) -> ModelPlan {
        let layers = (0..cfg.n_layer)
            .map(|i| {
                let p = format!("{i:02}.");
                LayerPlan {
                    index: i,
                    attn_gain: format!("{p}attn.gain"),
                    wq: format!("{p}attn.wq"),
                    wk: format!("{p}attn.wk"),
                    wv: format!("{p}attn.wv"),
                    wo: format!("{p}attn.wo"),
                    mlp_gain: format!("{p}mlp.gain"),
                    w1: format!("{p}mlp.w1"),
                    w2: format!("{p}mlp.w2"),
                }
            })
            .collect();
        ModelPlan { layers, final_gain: "final.gain".into(), out: "out".into() }
    }

    /// Every quantizable linear node the plan applies, in execution order.
    pub fn linear_names(&self) -> Vec<&str> {
        let mut names = Vec::with_capacity(self.layers.len() * 6 + 1);
        for l in &self.layers {
            names.extend([
                l.wq.as_str(),
                l.wk.as_str(),
                l.wv.as_str(),
                l.wo.as_str(),
                l.w1.as_str(),
                l.w2.as_str(),
            ]);
        }
        names.push(self.out.as_str());
        names
    }
}

/// Walk the plan over a residual-stream matrix `h` (rows × d_model),
/// applying every linear through `lin` and delegating the attention core
/// to `attend(layer, q, k, v) -> att_out`. Returns the output-head
/// logits. `h` is mutated in place (residual stream).
///
/// The operation order — rmsnorm, q/k/v, attend, wo, residual add,
/// rmsnorm, w1, gelu, w2, residual add, final rmsnorm, out — is exactly
/// the order of the original hand-inlined forwards, element-for-element,
/// which is what keeps the plan walk bit-identical to them.
pub fn walk<A>(
    plan: &ModelPlan,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    h: &mut Mat,
    mut capture: Option<&mut CalibCapture>,
    attend: A,
) -> Result<Mat>
where
    A: FnMut(&LayerPlan, &Mat, &Mat, &Mat) -> Result<Mat>,
{
    walk_layers(plan, store, lin, h, capture.as_deref_mut(), attend, 0, plan.layers.len())?;
    finish_walk(plan, store, lin, h, capture)
}

/// Walk a contiguous slice `lo..hi` of the plan's layers over the
/// residual stream `h`, without the final norm / output head. This is the
/// unit a pipeline stage executes: running `walk_layers(0..n)` followed by
/// [`finish_walk`] performs exactly the same operations in exactly the
/// same order as [`walk`], so cutting the layer list at any boundary is
/// bit-identical by construction.
pub fn walk_layers<A>(
    plan: &ModelPlan,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    h: &mut Mat,
    mut capture: Option<&mut CalibCapture>,
    mut attend: A,
    lo: usize,
    hi: usize,
) -> Result<()>
where
    A: FnMut(&LayerPlan, &Mat, &Mat, &Mat) -> Result<Mat>,
{
    let gain = |name: &str| -> Result<Vec<f32>> {
        Ok(store
            .get(name)
            .with_context(|| format!("missing {name}"))?
            .data
            .clone())
    };
    for layer in &plan.layers[lo..hi] {
        // ---- attention ----
        let a = rmsnorm(h, &gain(&layer.attn_gain)?);
        if let Some(cap) = capture.as_deref_mut() {
            cap.offer(&layer.wq, &a);
            cap.offer(&layer.wk, &a);
            cap.offer(&layer.wv, &a);
        }
        let q = lin.apply(&layer.wq, &a)?;
        let k = lin.apply(&layer.wk, &a)?;
        let v = lin.apply(&layer.wv, &a)?;
        let att_out = attend(layer, &q, &k, &v)?;
        if let Some(cap) = capture.as_deref_mut() {
            cap.offer(&layer.wo, &att_out);
        }
        let proj = lin.apply(&layer.wo, &att_out)?;
        for i in 0..h.data.len() {
            h.data[i] += proj.data[i];
        }

        // ---- mlp ----
        let m = rmsnorm(h, &gain(&layer.mlp_gain)?);
        if let Some(cap) = capture.as_deref_mut() {
            cap.offer(&layer.w1, &m);
        }
        let mut hidden = lin.apply(&layer.w1, &m)?;
        for x in hidden.data.iter_mut() {
            *x = gelu_tanh(*x);
        }
        if let Some(cap) = capture.as_deref_mut() {
            cap.offer(&layer.w2, &hidden);
        }
        let mlp_out = lin.apply(&layer.w2, &hidden)?;
        for i in 0..h.data.len() {
            h.data[i] += mlp_out.data[i];
        }
    }
    Ok(())
}

/// The tail of the plan walk: final rmsnorm + output head over a residual
/// stream that has already been carried through every layer (by [`walk`]
/// or by the last pipeline stage's [`walk_layers`]).
pub fn finish_walk(
    plan: &ModelPlan,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    h: &Mat,
    mut capture: Option<&mut CalibCapture>,
) -> Result<Mat> {
    let g = store
        .get(&plan.final_gain)
        .with_context(|| format!("missing {}", plan.final_gain))?
        .data
        .clone();
    let hf = rmsnorm(h, &g);
    if let Some(cap) = capture.as_deref_mut() {
        cap.offer(&plan.out, &hf);
    }
    lin.apply(&plan.out, &hf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CONFIG_S;

    #[test]
    fn plan_names_match_param_specs() {
        let cfg = CONFIG_S;
        let plan = ModelPlan::of(&cfg);
        assert_eq!(plan.layers.len(), cfg.n_layer);
        // every quantizable spec appears as exactly one linear node
        let mut want = cfg.quantizable_names();
        let mut got: Vec<String> =
            plan.linear_names().iter().map(|s| s.to_string()).collect();
        want.sort();
        got.sort();
        assert_eq!(got, want);
        // norm gains are addressed too
        let specs = cfg.param_specs();
        for l in &plan.layers {
            for gain in [&l.attn_gain, &l.mlp_gain] {
                assert!(specs.iter().any(|s| &s.name == gain), "missing {gain}");
            }
        }
        assert!(specs.iter().any(|s| s.name == plan.final_gain));
    }

    #[test]
    fn linear_names_follow_execution_order() {
        let cfg = CONFIG_S;
        let plan = ModelPlan::of(&cfg);
        let names = plan.linear_names();
        assert_eq!(names.len(), cfg.n_layer * 6 + 1);
        assert_eq!(names[0], "00.attn.wq");
        assert_eq!(names[5], "00.mlp.w2");
        assert_eq!(*names.last().unwrap(), "out");
    }
}
