//! Per-group code histograms → quantized rANS frequency tables.
//!
//! The alphabet for a `b`-bit group is the `2^b` offset codes
//! `u = c − lo ∈ [0, 2^b)` plus one trailing **escape** symbol for codes
//! outside the clamp range (index `2^b`). Babai-rounded GLVQ codes always
//! land in range, but the escape keeps the coder total: any i32 can be
//! represented, with the raw value carried out-of-band
//! ([`super::stream::RansChunk::escapes`]).
//!
//! Counts get **Laplace (+1) smoothing** so every symbol has nonzero mass
//! — a code value the calibration group never produced still decodes, at
//! the cost of a sliver of rate. The smoothed counts are then quantized to
//! a 12-bit table (sum exactly [`PROB_SCALE`], every entry ≥ 1) with
//! largest-first correction of the rounding drift.

use crate::entropy::rans::PROB_SCALE;
use crate::quant::pack::code_range;

/// Number of symbols for a `bits`-wide code alphabet (incl. escape).
pub fn alphabet_size(bits: u8) -> usize {
    (1usize << bits) + 1
}

/// Index of the escape symbol.
pub fn escape_symbol(bits: u8) -> usize {
    1usize << bits
}

/// A quantized per-group frequency table.
#[derive(Clone, Debug, PartialEq)]
pub struct CodeHistogram {
    pub bits: u8,
    /// One 12-bit frequency per symbol; `freqs.len() == alphabet_size`,
    /// every entry ≥ 1, `Σ freqs == PROB_SCALE`.
    pub freqs: Vec<u16>,
}

impl CodeHistogram {
    /// Build from raw codes with Laplace smoothing. `bits` must be in
    /// `1..=8` — the crate-wide code-width invariant, enforced by
    /// [`code_range`] (same contract as `PackedCodes::pack`), which also
    /// keeps the alphabet (≤ 257) below `PROB_SCALE`.
    pub fn build(codes: &[i32], bits: u8) -> CodeHistogram {
        let s = alphabet_size(bits);
        let (lo, hi) = code_range(bits);
        let mut counts = vec![1u64; s];
        for &c in codes {
            let idx = if c >= lo && c <= hi { (c - lo) as usize } else { s - 1 };
            counts[idx] += 1;
        }
        CodeHistogram { bits, freqs: quantize_freqs(&counts, PROB_SCALE) }
    }

    /// Reconstruct from a deserialized table (validates the invariants).
    pub fn from_freqs(bits: u8, freqs: Vec<u16>) -> Result<CodeHistogram, String> {
        if freqs.len() != alphabet_size(bits) {
            return Err(format!(
                "frequency table has {} entries, want {}",
                freqs.len(),
                alphabet_size(bits)
            ));
        }
        let sum: u32 = freqs.iter().map(|&f| f as u32).sum();
        if sum != PROB_SCALE || freqs.iter().any(|&f| f == 0) {
            return Err(format!("frequency table sums to {sum}, want {PROB_SCALE} (all > 0)"));
        }
        Ok(CodeHistogram { bits, freqs })
    }

    /// Symbol index for a code value.
    #[inline]
    pub fn symbol_of(&self, c: i32) -> usize {
        let (lo, hi) = code_range(self.bits);
        if c >= lo && c <= hi {
            (c - lo) as usize
        } else {
            escape_symbol(self.bits)
        }
    }

    /// Cumulative starts per symbol (`starts[s] = Σ_{t<s} freqs[t]`).
    pub fn starts(&self) -> Vec<u32> {
        let mut out = vec![0u32; self.freqs.len()];
        let mut cum = 0u32;
        for (o, &f) in out.iter_mut().zip(&self.freqs) {
            *o = cum;
            cum += f as u32;
        }
        out
    }

    /// Expand to the 4096-entry slot → symbol decode table.
    pub fn decode_table(&self) -> DecodeTable {
        let _sp = crate::span!("rans_table_expand");
        let starts = self.starts();
        let mut slots = vec![0u16; PROB_SCALE as usize];
        for (sym, (&st, &f)) in starts.iter().zip(&self.freqs).enumerate() {
            for slot in st..st + f as u32 {
                slots[slot as usize] = sym as u16;
            }
        }
        DecodeTable { starts, freqs: self.freqs.clone(), slots }
    }

    /// Serialized size of the table inside the `.glvq` v2 container
    /// (u16 per symbol).
    pub fn table_bytes(&self) -> usize {
        2 * self.freqs.len()
    }

    /// Empirical entropy of the quantized table in bits/symbol — the rate
    /// the coder approaches on matching data.
    pub fn entropy_bits(&self) -> f64 {
        let total = PROB_SCALE as f64;
        self.freqs
            .iter()
            .map(|&f| {
                let p = f as f64 / total;
                -p * p.log2()
            })
            .sum()
    }
}

/// Slot-indexed decode view of a histogram.
pub struct DecodeTable {
    pub starts: Vec<u32>,
    pub freqs: Vec<u16>,
    /// 12-bit slot → symbol
    pub slots: Vec<u16>,
}

/// Quantize positive counts to frequencies with sum exactly `target` and
/// every entry ≥ 1 (assumes `counts.len() <= target`).
pub fn quantize_freqs(counts: &[u64], target: u32) -> Vec<u16> {
    assert!(!counts.is_empty() && counts.len() <= target as usize);
    let total: u64 = counts.iter().sum();
    let mut freqs: Vec<u32> = counts
        .iter()
        .map(|&c| (((c * target as u64) / total).max(1)) as u32)
        .collect();
    let mut sum: u32 = freqs.iter().sum();
    // Rounding drift is at most a few entries per symbol; push it onto the
    // heaviest symbols where the relative rate loss is smallest.
    while sum > target {
        let i = (0..freqs.len()).max_by_key(|&i| freqs[i]).unwrap();
        debug_assert!(freqs[i] > 1);
        freqs[i] -= 1;
        sum -= 1;
    }
    while sum < target {
        let i = (0..freqs.len()).max_by_key(|&i| freqs[i]).unwrap();
        freqs[i] += 1;
        sum += 1;
    }
    freqs.into_iter().map(|f| f as u16).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    #[test]
    fn quantized_table_invariants_hold() {
        proptest(60, |rig| {
            let bits = rig.usize_in(1, 8) as u8;
            let (lo, hi) = code_range(bits);
            let n = rig.usize_in(0, 400);
            let codes: Vec<i32> = (0..n)
                .map(|_| {
                    if rig.usize_in(0, 9) == 0 {
                        // occasional out-of-range code
                        if rig.bool() {
                            hi + 1 + rig.usize_in(0, 5) as i32
                        } else {
                            lo - 1 - rig.usize_in(0, 5) as i32
                        }
                    } else {
                        rig.usize_in(0, (hi - lo) as usize) as i32 + lo
                    }
                })
                .collect();
            let h = CodeHistogram::build(&codes, bits);
            assert_eq!(h.freqs.len(), alphabet_size(bits));
            assert_eq!(h.freqs.iter().map(|&f| f as u32).sum::<u32>(), PROB_SCALE);
            assert!(h.freqs.iter().all(|&f| f >= 1));
            for &c in &codes {
                let s = h.symbol_of(c);
                assert!(s < alphabet_size(bits));
                if c < lo || c > hi {
                    assert_eq!(s, escape_symbol(bits));
                }
            }
        });
    }

    #[test]
    fn decode_table_partitions_all_slots() {
        let codes: Vec<i32> = (-2..2).cycle().take(100).collect();
        let h = CodeHistogram::build(&codes, 2);
        let t = h.decode_table();
        assert_eq!(t.slots.len(), PROB_SCALE as usize);
        // every slot maps to the symbol whose [start, start+freq) covers it
        for (slot, &sym) in t.slots.iter().enumerate() {
            let s = sym as usize;
            let st = t.starts[s];
            let f = t.freqs[s] as u32;
            assert!((slot as u32) >= st && (slot as u32) < st + f, "slot {slot} sym {sym}");
        }
    }

    #[test]
    fn skew_concentrates_mass() {
        let mut codes = vec![0i32; 1000];
        codes.extend_from_slice(&[1, -1, 1, -1]);
        let h = CodeHistogram::build(&codes, 3);
        let zero_sym = h.symbol_of(0);
        assert!(h.freqs[zero_sym] as u32 > PROB_SCALE * 8 / 10, "{:?}", h.freqs);
        assert!(h.entropy_bits() < 1.0, "{}", h.entropy_bits());
    }

    #[test]
    fn single_symbol_and_all_escape_degenerate_tables() {
        // single-symbol: everything at code 0
        let h = CodeHistogram::build(&vec![0i32; 500], 4);
        assert_eq!(h.freqs.iter().map(|&f| f as u32).sum::<u32>(), PROB_SCALE);
        assert!(h.freqs.iter().all(|&f| f >= 1));
        // all-escape: every code far out of range
        let h = CodeHistogram::build(&vec![9999i32; 500], 4);
        assert!(h.freqs[escape_symbol(4)] as u32 > PROB_SCALE / 2);
        assert!(h.freqs.iter().all(|&f| f >= 1));
    }

    #[test]
    fn from_freqs_validates() {
        assert!(CodeHistogram::from_freqs(2, vec![1024; 4]).is_err()); // wrong len
        assert!(CodeHistogram::from_freqs(2, vec![1000, 1000, 1000, 1000, 96]).is_ok());
        assert!(CodeHistogram::from_freqs(2, vec![2096, 1000, 1000, 0, 96]).is_err()); // zero
        assert!(CodeHistogram::from_freqs(2, vec![1000, 1000, 1000, 1000, 97]).is_err()); // sum
    }
}
