//! N-way interleaved rANS streams + the chunked [`RansCodes`] payload.
//!
//! Two axes of structure:
//!
//! - **Lane interleaving** (within a chunk): `lanes` independent rANS
//!   states share one byte stream round-robin (`symbol i → lane i % N`).
//!   The encoder walks symbols in reverse pushing renorm bytes, reverses
//!   the buffer once, and stores the final states; the decoder walks
//!   forward pulling bytes — a data-parallel decode loop with no
//!   per-lane byte bookkeeping.
//! - **Chunking** (across a group): the code vector is split into
//!   `chunk_len`-symbol chunks, each an independent stream. The streaming
//!   matvec decodes only the chunks covering the panel it needs
//!   ([`RansCodes::decode_range_into`]) instead of the whole group. The
//!   quantization pipeline aligns `chunk_len` to whole panel rows
//!   (a multiple of the group width) so panels touch the minimum number
//!   of chunks.
//!
//! Escape codes (outside the clamp range) are carried per chunk as raw
//! i32 values in symbol order; the decoder substitutes them when it pops
//! an escape symbol.

use crate::entropy::histogram::{escape_symbol, CodeHistogram, DecodeTable};
use crate::entropy::rans;
use crate::quant::pack::code_range;

/// Default interleave factor.
pub const DEFAULT_LANES: u8 = 4;
/// Default chunk size in symbols (pipeline aligns this to group rows).
pub const DEFAULT_CHUNK: usize = 4096;

/// One independently decodable rANS stream.
#[derive(Clone, Debug, PartialEq)]
pub struct RansChunk {
    /// final encoder states, one per lane (decoder starts from these)
    pub states: Vec<u32>,
    /// the shared renormalization byte stream (decoder reads forward)
    pub bytes: Vec<u8>,
    /// raw values for escape symbols, in symbol order
    pub escapes: Vec<i32>,
}

impl RansChunk {
    /// Bytes this chunk occupies in the container payload.
    pub fn payload_bytes(&self) -> usize {
        4 * self.states.len() + self.bytes.len() + 4 * self.escapes.len()
    }
}

/// Encode `codes` as one interleaved stream against `hist`.
pub fn encode_chunk(codes: &[i32], hist: &CodeHistogram, lanes: usize) -> RansChunk {
    debug_assert!(lanes >= 1);
    let starts = hist.starts();
    let esc = escape_symbol(hist.bits);

    let mut escapes = Vec::new();
    let symbols: Vec<u16> = codes
        .iter()
        .map(|&c| {
            let s = hist.symbol_of(c);
            if s == esc {
                escapes.push(c);
            }
            s as u16
        })
        .collect();

    let mut states = vec![rans::initial_state(); lanes];
    let mut bytes = Vec::with_capacity(codes.len() / 2 + 8);
    for i in (0..symbols.len()).rev() {
        let s = symbols[i] as usize;
        rans::put(
            &mut states[i % lanes],
            &mut bytes,
            starts[s],
            hist.freqs[s] as u32,
        );
    }
    bytes.reverse();
    RansChunk { states, bytes, escapes }
}

/// Decode exactly `out.len()` symbols from `chunk`.
pub fn decode_chunk_into(
    chunk: &RansChunk,
    table: &DecodeTable,
    bits: u8,
    out: &mut [i32],
) {
    let lanes = chunk.states.len().max(1);
    let esc = escape_symbol(bits);
    let lo = code_range(bits).0;
    let mut states = chunk.states.clone();
    let mut pos = 0usize;
    let mut ei = 0usize;
    for (i, slot_out) in out.iter_mut().enumerate() {
        let lane = i % lanes;
        let x = states[lane];
        let sym = table.slots[rans::slot(x) as usize] as usize;
        states[lane] = rans::advance(
            x,
            table.starts[sym],
            table.freqs[sym] as u32,
            &chunk.bytes,
            &mut pos,
        );
        *slot_out = if sym == esc {
            let v = chunk.escapes[ei];
            ei += 1;
            v
        } else {
            sym as i32 + lo
        };
    }
    debug_assert_eq!(pos, chunk.bytes.len(), "stream not fully consumed");
    debug_assert_eq!(ei, chunk.escapes.len(), "escapes not fully consumed");
}

/// Entropy-coded code payload: a shared per-group histogram + independent
/// chunk streams. The variable-rate alternative to
/// [`crate::quant::pack::PackedCodes`].
#[derive(Clone, Debug, PartialEq)]
pub struct RansCodes {
    pub bits: u8,
    /// total number of codes
    pub n: usize,
    /// symbols per chunk (the last chunk may be shorter)
    pub chunk_len: usize,
    /// interleave factor
    pub lanes: u8,
    pub hist: CodeHistogram,
    pub chunks: Vec<RansChunk>,
}

impl RansCodes {
    /// Encode a full code vector. `chunk_len` bounds the decode
    /// granularity; `lanes` is the interleave factor.
    pub fn encode(codes: &[i32], bits: u8, chunk_len: usize, lanes: u8) -> RansCodes {
        let chunk_len = chunk_len.max(1);
        let lanes = lanes.max(1);
        let hist = CodeHistogram::build(codes, bits);
        let chunks = codes
            .chunks(chunk_len)
            .map(|c| encode_chunk(c, &hist, lanes as usize))
            .collect();
        RansCodes { bits, n: codes.len(), chunk_len, lanes, hist, chunks }
    }

    /// Number of symbols stored in chunk `ci`.
    pub fn chunk_symbols(&self, ci: usize) -> usize {
        let start = ci * self.chunk_len;
        self.chunk_len.min(self.n - start)
    }

    /// Decode the whole payload.
    pub fn decode(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.n];
        self.decode_into(&mut out);
        out
    }

    /// Decode the whole payload into a caller buffer (`len == n`).
    pub fn decode_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.n);
        let table = self.hist.decode_table();
        for (ci, chunk) in self.chunks.iter().enumerate() {
            let start = ci * self.chunk_len;
            let len = self.chunk_symbols(ci);
            decode_chunk_into(chunk, &table, self.bits, &mut out[start..start + len]);
        }
    }

    /// Decode codes `[start, start+out.len())`. Whole covering chunks are
    /// decoded into scratch and the requested window copied out — rANS
    /// streams have no mid-stream entry points, chunking IS the random
    /// access. Cost is proportional to the chunks touched, not the group.
    ///
    /// Convenience wrapper that builds the decode table and scratch per
    /// call; hot paths should build the table once per group and reuse a
    /// scratch buffer via [`RansCodes::decode_range_with`].
    pub fn decode_range_into(&self, start: usize, out: &mut [i32]) {
        let table = self.hist.decode_table();
        let mut scratch = Vec::new();
        self.decode_range_with(start, out, &table, &mut scratch);
    }

    /// Allocation-amortized range decode: the caller owns the expanded
    /// decode `table` (one per group) and a reusable `scratch` buffer.
    pub fn decode_range_with(
        &self,
        start: usize,
        out: &mut [i32],
        table: &DecodeTable,
        scratch: &mut Vec<i32>,
    ) {
        assert!(start + out.len() <= self.n);
        if out.is_empty() {
            return;
        }
        let first = start / self.chunk_len;
        let last = (start + out.len() - 1) / self.chunk_len;
        for ci in first..=last {
            let cstart = ci * self.chunk_len;
            let clen = self.chunk_symbols(ci);
            // fast path: chunk fully inside the request window → decode
            // straight into the output
            let w0 = start.max(cstart);
            let w1 = (start + out.len()).min(cstart + clen);
            if w0 == cstart && w1 == cstart + clen {
                decode_chunk_into(
                    &self.chunks[ci],
                    table,
                    self.bits,
                    &mut out[cstart - start..cstart - start + clen],
                );
            } else {
                if scratch.len() < clen {
                    scratch.resize(clen, 0);
                }
                decode_chunk_into(&self.chunks[ci], table, self.bits, &mut scratch[..clen]);
                out[w0 - start..w1 - start].copy_from_slice(&scratch[w0 - cstart..w1 - cstart]);
            }
        }
    }

    /// Chunk indices `[first, last]` covering a symbol range.
    pub fn chunk_span(&self, start: usize, len: usize) -> (usize, usize) {
        if len == 0 || self.n == 0 {
            return (0, 0);
        }
        (start / self.chunk_len, (start + len - 1) / self.chunk_len)
    }

    /// True compressed payload size: frequency table + all chunks.
    pub fn payload_bytes(&self) -> usize {
        self.hist.table_bytes() + self.chunks.iter().map(|c| c.payload_bytes()).sum::<usize>()
    }

    /// Payload bytes touched when decoding a symbol range (bytes-moved
    /// model for [`crate::coordinator::decode_stream::DecodeStats`]). The
    /// frequency table is charged with the first chunk.
    pub fn range_payload_bytes(&self, start: usize, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        let (first, last) = self.chunk_span(start, len);
        let mut bytes: usize = (first..=last).map(|ci| self.chunks[ci].payload_bytes()).sum();
        if first == 0 {
            bytes += self.hist.table_bytes();
        }
        bytes
    }

    /// The fixed-width payload size this group would occupy un-coded
    /// (`⌈n·b/8⌉` — Eq. 26's `m·n·b/8` term).
    pub fn fixed_payload_bytes(&self) -> usize {
        (self.n * self.bits as usize).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    fn random_codes(rig: &mut crate::util::proptest::Rig, bits: u8, n: usize) -> Vec<i32> {
        let (lo, hi) = code_range(bits);
        (0..n)
            .map(|_| rig.usize_in(0, (hi - lo) as usize) as i32 + lo)
            .collect()
    }

    #[test]
    fn roundtrip_all_bit_widths_random() {
        proptest(80, |rig| {
            let bits = rig.usize_in(1, 8) as u8;
            let n = rig.usize_in(0, 600);
            let chunk = rig.usize_in(1, 200);
            let lanes = rig.usize_in(1, 8) as u8;
            let codes = random_codes(rig, bits, n);
            let rc = RansCodes::encode(&codes, bits, chunk, lanes);
            assert_eq!(rc.decode(), codes, "bits={bits} n={n} chunk={chunk} lanes={lanes}");
        });
    }

    #[test]
    fn roundtrip_gaussian_codes_and_ranges() {
        proptest(40, |rig| {
            let bits = rig.usize_in(2, 8) as u8;
            let n = rig.usize_in(1, 800);
            let sigma = (1 << (bits - 1)) as f32 / 6.0;
            let codes: Vec<i32> = (0..n)
                .map(|_| crate::quant::pack::clamp_code(rig.rng.normal_f32() * sigma, bits))
                .collect();
            let rc = RansCodes::encode(&codes, bits, 128, DEFAULT_LANES);
            assert_eq!(rc.decode(), codes);

            // arbitrary sub-range decode matches the full decode
            let start = rig.usize_in(0, n - 1);
            let len = rig.usize_in(0, n - start);
            let mut out = vec![0i32; len];
            rc.decode_range_into(start, &mut out);
            assert_eq!(&out[..], &codes[start..start + len]);
        });
    }

    #[test]
    fn degenerate_single_symbol_and_all_escape() {
        for bits in [1u8, 3, 8] {
            // single symbol
            let codes = vec![code_range(bits).0; 1000];
            let rc = RansCodes::encode(&codes, bits, 256, 4);
            assert_eq!(rc.decode(), codes);
            // single-symbol streams compress massively
            assert!(rc.payload_bytes() < rc.fixed_payload_bytes().max(64));

            // all escape (out-of-range raw values)
            let codes: Vec<i32> = (0..500).map(|i| 100_000 + i).collect();
            let rc = RansCodes::encode(&codes, bits, 128, 2);
            assert_eq!(rc.decode(), codes);
        }
    }

    #[test]
    fn empty_and_single_code_vectors() {
        let rc = RansCodes::encode(&[], 4, 64, 4);
        assert_eq!(rc.decode(), Vec::<i32>::new());
        assert_eq!(rc.chunks.len(), 0);

        let rc = RansCodes::encode(&[-3], 4, 64, 4);
        assert_eq!(rc.decode(), vec![-3]);
        let mut one = [0i32; 1];
        rc.decode_range_into(0, &mut one);
        assert_eq!(one[0], -3);
    }

    #[test]
    fn gaussian_codes_beat_fixed_width_by_15_percent() {
        // Babai codes concentrate well inside the clamp range; model that
        // as a discrete Gaussian at σ = range/16 and require the ≥15%
        // saving the ISSUE acceptance criterion demands for b ≥ 3.
        let mut rng = crate::util::rng::Rng::new(7);
        for bits in 3u8..=8 {
            let sigma = (1 << (bits - 1)) as f32 / 8.0;
            let codes: Vec<i32> = (0..16384)
                .map(|_| crate::quant::pack::clamp_code(rng.normal_f32() * sigma, bits))
                .collect();
            let rc = RansCodes::encode(&codes, bits, DEFAULT_CHUNK, DEFAULT_LANES);
            assert_eq!(rc.decode(), codes, "bits={bits}");
            let fixed = rc.fixed_payload_bytes() as f64;
            let coded = rc.payload_bytes() as f64;
            assert!(
                coded <= 0.85 * fixed,
                "bits={bits}: coded {coded} vs fixed {fixed} ({}%)",
                100.0 * coded / fixed
            );
        }
    }

    #[test]
    fn range_byte_accounting_is_chunk_granular() {
        let codes: Vec<i32> = (0..1000).map(|i| (i % 3) - 1).collect();
        let rc = RansCodes::encode(&codes, 2, 100, 4);
        assert_eq!(rc.chunks.len(), 10);
        let total: usize = rc.payload_bytes();
        // touching everything charges exactly the whole payload
        assert_eq!(rc.range_payload_bytes(0, 1000), total);
        // a one-chunk window charges one chunk (+ table iff chunk 0)
        let one = rc.range_payload_bytes(500, 100);
        assert_eq!(one, rc.chunks[5].payload_bytes());
        assert_eq!(
            rc.range_payload_bytes(0, 100),
            rc.chunks[0].payload_bytes() + rc.hist.table_bytes()
        );
        assert_eq!(rc.range_payload_bytes(0, 0), 0);
    }
}
