//! Core range ANS (rANS) coder: 32-bit state, 12-bit quantized frequency
//! tables, byte-wise renormalization (the "ryg_rans" byte variant).
//!
//! Invariants:
//! - Encoder state lives in `[RANS_L, RANS_L·256)` after each `put`; the
//!   decoder renormalizes back above `RANS_L` after each `advance`.
//! - Symbols are encoded in *reverse* order and the emitted byte buffer is
//!   reversed once at the end, so the decoder reads bytes forward. This is
//!   what makes N-way lane interleaving ([`super::stream`]) work: decode
//!   step `i` pulls exactly the bytes encode step `i` pushed.
//! - All frequencies are 12-bit (`PROB_SCALE` = 4096) and strictly
//!   positive ([`super::histogram`] guarantees this), so `put`/`advance`
//!   never divide by zero and the u32 state arithmetic cannot overflow:
//!   `x_max = (RANS_L>>12)<<8 · freq ≤ 2^31` and `x<<8 < 2^31` at renorm.

/// Number of probability bits; frequency tables sum to `1 << PROB_BITS`.
pub const PROB_BITS: u32 = 12;
/// Total frequency mass (4096).
pub const PROB_SCALE: u32 = 1 << PROB_BITS;
/// Lower bound of the normalized state interval.
pub const RANS_L: u32 = 1 << 23;

/// Fresh encoder state (also the decoder's terminal state for an empty
/// stream).
#[inline]
pub fn initial_state() -> u32 {
    RANS_L
}

/// Encode one symbol with cumulative range `[start, start+freq)` into
/// `state`, appending renormalization bytes to `out` (low byte first;
/// the whole buffer is reversed once after the last symbol).
#[inline]
pub fn put(state: &mut u32, out: &mut Vec<u8>, start: u32, freq: u32) {
    debug_assert!(freq > 0 && freq <= PROB_SCALE);
    debug_assert!(start + freq <= PROB_SCALE);
    let x_max = ((RANS_L >> PROB_BITS) << 8) * freq;
    let mut x = *state;
    while x >= x_max {
        out.push((x & 0xFF) as u8);
        x >>= 8;
    }
    *state = ((x / freq) << PROB_BITS) + (x % freq) + start;
}

/// The 12-bit slot the decoder resolves to a symbol.
#[inline]
pub fn slot(state: u32) -> u32 {
    state & (PROB_SCALE - 1)
}

/// Consume the symbol `(start, freq)` that `slot(state)` resolved to,
/// renormalizing from `bytes` (forward cursor `pos`). Returns the new
/// state. Panics on a malformed stream: the container CRC rejects
/// *accidental* corruption before decode, and the v2 reader validates
/// structural invariants (lengths, counts, table sums); a deliberately
/// crafted stream body is outside the threat model and fails loudly here
/// rather than decoding garbage.
#[inline]
pub fn advance(state: u32, start: u32, freq: u32, bytes: &[u8], pos: &mut usize) -> u32 {
    debug_assert!(freq > 0);
    let mut x = freq * (state >> PROB_BITS) + slot(state) - start;
    while x < RANS_L {
        x = (x << 8) | bytes[*pos] as u32;
        *pos += 1;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    /// Single-lane encode/decode helper over a tiny fixed table.
    fn roundtrip(symbols: &[usize], freqs: &[u32]) -> Vec<usize> {
        let mut starts = vec![0u32; freqs.len()];
        let mut cum = 0;
        for (i, &f) in freqs.iter().enumerate() {
            starts[i] = cum;
            cum += f;
        }
        assert_eq!(cum, PROB_SCALE);

        let mut state = initial_state();
        let mut bytes = Vec::new();
        for &s in symbols.iter().rev() {
            put(&mut state, &mut bytes, starts[s], freqs[s]);
        }
        bytes.reverse();

        let mut out = Vec::with_capacity(symbols.len());
        let mut pos = 0;
        let mut x = state;
        for _ in 0..symbols.len() {
            let sl = slot(x);
            let sym = starts.iter().rposition(|&st| st <= sl).unwrap();
            x = advance(x, starts[sym], freqs[sym], &bytes, &mut pos);
            out.push(sym);
        }
        assert_eq!(pos, bytes.len(), "decoder must consume the whole stream");
        assert_eq!(x, initial_state(), "state must return to the initial value");
        out
    }

    #[test]
    fn uniform_table_roundtrip() {
        let freqs = vec![PROB_SCALE / 4; 4];
        let syms = vec![0, 1, 2, 3, 3, 2, 1, 0, 0, 0, 3];
        assert_eq!(roundtrip(&syms, &freqs), syms);
    }

    #[test]
    fn skewed_table_roundtrip_random() {
        let freqs = vec![3900, 100, 90, 6];
        proptest(40, |rig| {
            let n = rig.usize_in(0, 500);
            let syms: Vec<usize> = (0..n)
                .map(|_| {
                    // sample roughly by mass
                    let r = rig.usize_in(0, 4095);
                    if r < 3900 {
                        0
                    } else if r < 4000 {
                        1
                    } else if r < 4090 {
                        2
                    } else {
                        3
                    }
                })
                .collect();
            assert_eq!(roundtrip(&syms, &freqs), syms);
        });
    }

    #[test]
    fn skewed_stream_is_compact() {
        // 4000/4096 mass on symbol 0 → ~0.1 bits/symbol; 4096 symbols of
        // the dominant class must take far fewer than 4096/8 fixed bytes.
        let freqs = vec![4000, 48, 32, 16];
        let syms = vec![0usize; 4096];
        let mut state = initial_state();
        let mut bytes = Vec::new();
        for _ in 0..syms.len() {
            put(&mut state, &mut bytes, 0, freqs[0]);
        }
        // ~4096·log2(4096/4000)/8 ≈ 18 bytes
        assert!(bytes.len() < 60, "{} bytes", bytes.len());
    }
}
