//! Entropy-coded lattice codes: a static-table rANS backend for the
//! `.glvq` container (v2).
//!
//! After Babai rounding, GLVQ's integer codes are far from uniform — they
//! concentrate in a discrete-Gaussian-like mass around zero, so the
//! fixed-width `m·n·b/8` payload of [`crate::quant::pack`] (Eq. 26)
//! systematically overpays relative to the codes' empirical entropy. This
//! module closes that gap losslessly:
//!
//! - [`rans`] — the core range-ANS coder: 32-bit state, 12-bit quantized
//!   frequency tables, byte renormalization.
//! - [`histogram`] — per-group code histograms with Laplace smoothing and
//!   an escape symbol for out-of-range codes, quantized to rANS tables.
//! - [`stream`] — N-way lane-interleaved encode/decode and the chunked
//!   [`stream::RansCodes`] payload the streaming matvec random-accesses.
//!
//! Integration points: [`crate::quant::traits::CodePayload`] (the
//! fixed-vs-entropy payload enum), `.glvq` v2 in
//! [`crate::quant::format`], `--entropy` in the quantization pipeline and
//! CLI, and the measured-with-entropy column of the Table-5 reproduction.
//! Future backends (tANS, dictionary-shared tables across groups) slot in
//! as further `CodePayload` variants.

pub mod histogram;
pub mod rans;
pub mod stream;

pub use histogram::CodeHistogram;
pub use stream::{RansChunk, RansCodes, DEFAULT_CHUNK, DEFAULT_LANES};
