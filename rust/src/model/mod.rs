//! Model metadata mirroring `python/compile/model.py` exactly: architecture
//! configs, canonical parameter specs (sorted names, shapes, quantizable
//! flags) and weight-store helpers.
//!
//! The manifest written by `aot.py` is the source of truth at runtime
//! ([`crate::runtime::engine`] parses it); this module provides the same
//! information natively so the pure-rust paths (native forward, quantizers,
//! experiments) work without artifacts present.

use crate::tensor::{Tensor, TensorStore};
use crate::util::rng::Rng;

/// Architecture hyperparameters (must match ModelConfig in model.py).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_train: usize,
    pub batch_eval: usize,
}

impl ModelConfig {
    pub const fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "s" => Some(CONFIG_S),
            "m" => Some(CONFIG_M),
            "l" => Some(CONFIG_L),
            _ => None,
        }
    }

    /// (name, shape, quantizable) in canonical sorted order — mirrors
    /// `ModelConfig.param_specs()` in model.py (tested for equality against
    /// the manifest in rust/tests/manifest_parity.rs).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let mut specs: Vec<ParamSpec> = Vec::new();
        let d = self.d_model;
        specs.push(ParamSpec::new("emb", vec![self.vocab, d], false));
        specs.push(ParamSpec::new("final.gain", vec![d], false));
        specs.push(ParamSpec::new("out", vec![d, self.vocab], true));
        specs.push(ParamSpec::new("pos", vec![self.seq_len, d], false));
        for i in 0..self.n_layer {
            let p = format!("{i:02}.");
            specs.push(ParamSpec::new(&format!("{p}attn.gain"), vec![d], false));
            specs.push(ParamSpec::new(&format!("{p}attn.wk"), vec![d, d], true));
            specs.push(ParamSpec::new(&format!("{p}attn.wo"), vec![d, d], true));
            specs.push(ParamSpec::new(&format!("{p}attn.wq"), vec![d, d], true));
            specs.push(ParamSpec::new(&format!("{p}attn.wv"), vec![d, d], true));
            specs.push(ParamSpec::new(&format!("{p}mlp.gain"), vec![d], false));
            specs.push(ParamSpec::new(&format!("{p}mlp.w1"), vec![d, self.d_ff], true));
            specs.push(ParamSpec::new(&format!("{p}mlp.w2"), vec![self.d_ff, d], true));
        }
        specs.sort_by(|a, b| a.name.cmp(&b.name));
        specs
    }

    pub fn param_count(&self) -> usize {
        self.param_specs()
            .iter()
            .map(|s| s.shape.iter().product::<usize>())
            .sum()
    }

    /// Names of the 2-D weights the quantizers compress.
    pub fn quantizable_names(&self) -> Vec<String> {
        self.param_specs()
            .into_iter()
            .filter(|s| s.quantizable)
            .map(|s| s.name)
            .collect()
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub quantizable: bool,
}

impl ParamSpec {
    fn new(name: &str, shape: Vec<usize>, quantizable: bool) -> ParamSpec {
        ParamSpec { name: name.to_string(), shape, quantizable }
    }
}

/// The S/M/L family — the substitution for Llama 7B/13B/70B.
pub const CONFIG_S: ModelConfig = ModelConfig {
    name: "s",
    vocab: 256,
    d_model: 128,
    n_layer: 4,
    n_head: 4,
    d_ff: 512,
    seq_len: 128,
    batch_train: 16,
    batch_eval: 8,
};

pub const CONFIG_M: ModelConfig = ModelConfig {
    name: "m",
    vocab: 256,
    d_model: 256,
    n_layer: 6,
    n_head: 8,
    d_ff: 1024,
    seq_len: 128,
    batch_train: 16,
    batch_eval: 8,
};

pub const CONFIG_L: ModelConfig = ModelConfig {
    name: "l",
    vocab: 256,
    d_model: 512,
    n_layer: 8,
    n_head: 8,
    d_ff: 2048,
    seq_len: 128,
    batch_train: 16,
    batch_eval: 8,
};

/// Initialize a parameter store with the same *distribution family* as
/// model.py's `init_params` (scaled normal; gains = 1). Bit-exact parity
/// with jax.random is not required — trained checkpoints flow through
/// `.gten` files — but shapes and scaling match.
pub fn init_params(cfg: &ModelConfig, seed: u64) -> TensorStore {
    let mut rng = Rng::new(seed);
    let mut store = TensorStore::new();
    for spec in cfg.param_specs() {
        let numel: usize = spec.shape.iter().product();
        let mut data = vec![0.0f32; numel];
        if spec.name.ends_with("gain") {
            data.fill(1.0);
        } else if spec.name == "pos" {
            rng.fill_normal(&mut data, 0.01);
        } else {
            let fan_in = spec.shape[0] as f32;
            let mut scale = 0.5 / fan_in.sqrt();
            if spec.name.ends_with("wo") || spec.name.ends_with("w2") {
                scale /= (2.0 * cfg.n_layer as f32).sqrt();
            }
            rng.fill_normal(&mut data, scale);
        }
        store.insert(&spec.name, Tensor::from_vec(&spec.shape, data));
    }
    store
}

/// Validate a store against a config (names + shapes).
pub fn validate_store(cfg: &ModelConfig, store: &TensorStore) -> Result<(), String> {
    for spec in cfg.param_specs() {
        match store.get(&spec.name) {
            None => return Err(format!("missing param {}", spec.name)),
            Some(t) if t.shape != spec.shape => {
                return Err(format!(
                    "shape mismatch for {}: {:?} vs {:?}",
                    spec.name, t.shape, spec.shape
                ))
            }
            _ => {}
        }
    }
    let expected: usize = cfg.param_specs().len();
    if store.entries.len() != expected {
        return Err(format!(
            "param count mismatch: store {} vs spec {}",
            store.entries.len(),
            expected
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_sorted_and_counted() {
        for cfg in [CONFIG_S, CONFIG_M, CONFIG_L] {
            let specs = cfg.param_specs();
            let names: Vec<&String> = specs.iter().map(|s| &s.name).collect();
            let mut sorted = names.clone();
            sorted.sort();
            assert_eq!(names, sorted);
            assert_eq!(specs.len(), 4 + 8 * cfg.n_layer);
        }
    }

    #[test]
    fn s_param_count_matches_python_export() {
        // aot.py printed: model s → 44 params, 1_118_848 weights (from the
        // `make artifacts` log); keep this pinned.
        assert_eq!(CONFIG_S.param_specs().len(), 4 + 8 * 4);
        assert_eq!(CONFIG_M.param_count(), 4_885_760);
    }

    #[test]
    fn quantizable_set() {
        let q = CONFIG_S.quantizable_names();
        assert!(q.contains(&"out".to_string()));
        assert!(q.contains(&"00.attn.wq".to_string()));
        assert!(!q.contains(&"emb".to_string()));
        assert_eq!(q.len(), 1 + 6 * CONFIG_S.n_layer);
    }

    #[test]
    fn init_and_validate_roundtrip() {
        let store = init_params(&CONFIG_S, 0);
        assert!(validate_store(&CONFIG_S, &store).is_ok());
        let mut broken = store.clone();
        broken.entries.remove("out");
        assert!(validate_store(&CONFIG_S, &broken).is_err());
    }

    #[test]
    fn gains_are_ones_and_weights_scaled() {
        let store = init_params(&CONFIG_S, 1);
        let gain = store.get("final.gain").unwrap();
        assert!(gain.data.iter().all(|&v| v == 1.0));
        let wq = store.get("00.attn.wq").unwrap();
        let std = crate::linalg::stats::std_dev(&wq.data);
        let expect = 0.5 / (CONFIG_S.d_model as f64).sqrt();
        assert!((std - expect).abs() < expect * 0.15, "std={std} expect={expect}");
    }
}
