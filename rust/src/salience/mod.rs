//! Salience-Determined Bit Allocation (SDBA) — paper §3.1, Eq. 3, adopted
//! from Slim-LLM.
//!
//! Groups receive b_g ∈ {N−1, N, N+1} bits with the balance constraint
//! |G_{N+1}| = |G_{N−1}| so the mean stays exactly N. Salience is the
//! KL divergence between the group's full-precision output distribution
//! (W_g X) and its base-precision quantized output (Ŵ_g X) — groups whose
//! outputs distort most at N bits are promoted.
//!
//! The promoted/demoted count k is found by the double-pointer search over
//! k ∈ [0, G/2]: a golden-section-style shrink on the (empirically convex)
//! total-distortion curve, O(log G) cost evaluations, matching the paper's
//! O(log m) claim.
//!
//! Fractional global rates (Table 3: 1.5, 1.0 bits) reuse the same salience
//! ordering: groups are split between ⌊t⌋ and ⌈t⌉ bits with the exact count
//! ratio that hits the target mean.

use crate::linalg::stats::kl_divergence;
use crate::linalg::Mat;

/// Per-group salience + distortion estimates at the three candidate widths.
#[derive(Clone, Debug)]
pub struct GroupSalience {
    /// group index in pipeline order
    pub index: usize,
    /// KL(WX || Ŵ_N X) — the promotion priority
    pub salience: f64,
    /// distortion (recon MSE) at N−1 / N / N+1 bits
    pub dist: [f64; 3],
}

/// Compute salience + distortion profile for one group using a fast RTN
/// proxy quantizer at each candidate width (the full GLVQ optimizer is far
/// too expensive to run G× per candidate; the paper's Slim-LLM heuristic is
/// likewise proxy-based).
pub fn group_salience(index: usize, w: &Mat, x: &Mat, base_bits: u8) -> GroupSalience {
    let full = w.matmul(x);
    let mut dist = [0.0f64; 3];
    let mut salience = 0.0f64;
    for (slot, delta) in [(-1i32, 0usize), (0, 1), (1, 2)] {
        let b = (base_bits as i32 + slot).clamp(1, 8) as u8;
        let w_hat = rtn_proxy(w, b);
        let qout = w_hat.matmul(x);
        let mse: f64 = full
            .data
            .iter()
            .zip(&qout.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        dist[delta] = mse;
        if slot == 0 {
            salience = kl_divergence(&full.data, &qout.data, 64);
        }
    }
    GroupSalience { index, salience, dist }
}

/// Minimal RTN used only as the salience proxy.
fn rtn_proxy(w: &Mat, bits: u8) -> Mat {
    let maxabs = w.max_abs().max(1e-12);
    let levels = ((1i64 << bits) - 1) as f32;
    let scale = 2.0 * maxabs / levels;
    let mut out = w.clone();
    for v in out.data.iter_mut() {
        let q = ((*v + maxabs) / scale).round().clamp(0.0, levels);
        *v = q * scale - maxabs;
    }
    out
}

/// A bit assignment for all groups.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    pub bits: Vec<u8>,
}

impl Allocation {
    pub fn mean_bits(&self) -> f64 {
        self.bits.iter().map(|&b| b as f64).sum::<f64>() / self.bits.len().max(1) as f64
    }

    pub fn uniform(n_groups: usize, bits: u8) -> Allocation {
        Allocation { bits: vec![bits; n_groups] }
    }
}

/// SDBA with integer target N: balanced ±1 promotion/demotion of the k most
/// and least salient groups; k minimizes the summed distortion estimate.
pub fn allocate_balanced(saliences: &[GroupSalience], base_bits: u8) -> Allocation {
    let g = saliences.len();
    if base_bits <= 1 {
        // demotion below 1 bit is impossible, so the balance constraint
        // forces the uniform allocation at the floor rate
        return Allocation::uniform(g, 1);
    }
    let mut order: Vec<usize> = (0..g).collect();
    // descending salience
    order.sort_by(|&a, &b| {
        saliences[b]
            .salience
            .partial_cmp(&saliences[a].salience)
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    let cost = |k: usize| -> f64 {
        let mut total = 0.0;
        for (rank, &gi) in order.iter().enumerate() {
            let d = &saliences[gi].dist;
            total += if rank < k {
                d[2] // promoted to N+1
            } else if rank >= g - k {
                d[0] // demoted to N−1
            } else {
                d[1]
            };
        }
        total
    };

    // double-pointer / golden-section shrink over k ∈ [0, g/2]
    let (mut lo, mut hi) = (0usize, g / 2);
    while hi - lo > 2 {
        let m1 = lo + (hi - lo) / 3;
        let m2 = hi - (hi - lo) / 3;
        if cost(m1) <= cost(m2) {
            hi = m2;
        } else {
            lo = m1;
        }
    }
    let mut best_k = lo;
    let mut best_cost = cost(lo);
    for k in lo + 1..=hi {
        let c = cost(k);
        if c < best_cost {
            best_cost = c;
            best_k = k;
        }
    }

    let mut bits = vec![base_bits; g];
    for (rank, &gi) in order.iter().enumerate() {
        if rank < best_k {
            bits[gi] = (base_bits + 1).min(8);
        } else if rank >= g - best_k {
            bits[gi] = base_bits.saturating_sub(1).max(1);
        }
    }
    Allocation { bits }
}

/// Fractional-rate allocation (paper §4.3): hit `target` mean bits exactly
/// (up to rounding on group count) by splitting groups between ⌊t⌋ and ⌈t⌉
/// in salience order (most salient get the extra bit).
pub fn allocate_fractional(saliences: &[GroupSalience], target: f64) -> Allocation {
    let g = saliences.len();
    let lo = target.floor().max(1.0) as u8;
    let hi = target.ceil().max(1.0) as u8;
    if lo == hi {
        return allocate_balanced(saliences, lo);
    }
    // n_hi groups at hi bits s.t. mean ≈ target
    let n_hi = ((target - lo as f64) * g as f64).round() as usize;
    let mut order: Vec<usize> = (0..g).collect();
    order.sort_by(|&a, &b| {
        saliences[b]
            .salience
            .partial_cmp(&saliences[a].salience)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut bits = vec![lo; g];
    for &gi in order.iter().take(n_hi) {
        bits[gi] = hi;
    }
    Allocation { bits }
}

/// Entry point: integer targets go through the balanced SDBA; fractional
/// targets through the hi/lo split.
pub fn allocate(saliences: &[GroupSalience], target_bits: f64) -> Allocation {
    if (target_bits - target_bits.round()).abs() < 1e-9 {
        allocate_balanced(saliences, target_bits.round() as u8)
    } else {
        allocate_fractional(saliences, target_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    fn fake_saliences(g: usize, seed: u64) -> Vec<GroupSalience> {
        let mut rng = Rng::new(seed);
        (0..g)
            .map(|i| {
                let s = rng.f64() * 10.0;
                // distortion decreases with bits, scaled by salience
                GroupSalience {
                    index: i,
                    salience: s,
                    dist: [4.0 * s + 1.0, 1.0 * s + 0.5, 0.3 * s + 0.2],
                }
            })
            .collect()
    }

    #[test]
    fn balanced_allocation_invariants() {
        proptest(30, |rig| {
            let g = rig.usize_in(2, 200);
            let base = rig.usize_in(2, 4) as u8;
            let sal = fake_saliences(g, rig.case as u64);
            let alloc = allocate_balanced(&sal, base);
            assert_eq!(alloc.bits.len(), g);
            let promoted = alloc.bits.iter().filter(|&&b| b == base + 1).count();
            let demoted = alloc.bits.iter().filter(|&&b| b == base - 1).count();
            assert_eq!(promoted, demoted, "|G_N+1| must equal |G_N-1|");
            assert!((alloc.mean_bits() - base as f64).abs() < 1e-9);
        });
    }

    #[test]
    fn promoted_groups_have_higher_salience_than_demoted() {
        let sal = fake_saliences(60, 3);
        let alloc = allocate_balanced(&sal, 2);
        let min_promoted = sal
            .iter()
            .zip(&alloc.bits)
            .filter(|(_, &b)| b == 3)
            .map(|(s, _)| s.salience)
            .fold(f64::INFINITY, f64::min);
        let max_demoted = sal
            .iter()
            .zip(&alloc.bits)
            .filter(|(_, &b)| b == 1)
            .map(|(s, _)| s.salience)
            .fold(f64::NEG_INFINITY, f64::max);
        if min_promoted.is_finite() && max_demoted.is_finite() {
            assert!(min_promoted >= max_demoted);
        }
    }

    #[test]
    fn fractional_targets_hit_mean() {
        proptest(20, |rig| {
            let g = rig.usize_in(8, 300);
            let sal = fake_saliences(g, rig.case as u64 + 100);
            for target in [1.5f64, 1.25, 2.5] {
                let alloc = allocate(&sal, target);
                assert!(
                    (alloc.mean_bits() - target).abs() <= 0.5 / g as f64 + 1e-2,
                    "g={g} target={target} mean={}",
                    alloc.mean_bits()
                );
            }
        });
    }

    #[test]
    fn integer_target_routes_to_balanced() {
        let sal = fake_saliences(40, 9);
        let a = allocate(&sal, 2.0);
        let b = allocate_balanced(&sal, 2);
        assert_eq!(a, b);
    }

    #[test]
    fn salience_computation_flags_wide_groups() {
        let mut rng = Rng::new(4);
        // low-variance group vs heavy-tailed group
        let w_small = Mat::random_normal(16, 32, 0.005, &mut rng);
        let mut w_big = Mat::random_normal(16, 32, 0.005, &mut rng);
        for i in 0..8 {
            w_big.data[i * 37] = 0.5; // inject outliers
        }
        let x = Mat::random_normal(32, 64, 1.0, &mut rng);
        let s_small = group_salience(0, &w_small, &x, 2);
        let s_big = group_salience(1, &w_big, &x, 2);
        assert!(s_big.dist[1] > s_small.dist[1]);
        // distortion must be monotone in bits
        for s in [&s_small, &s_big] {
            assert!(s.dist[0] >= s.dist[1] && s.dist[1] >= s.dist[2], "{:?}", s.dist);
        }
    }
}
