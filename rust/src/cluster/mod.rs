//! Cluster scale-out: pipeline-parallel stage execution and replicated
//! serving over the quantized container.
//!
//! Two independent axes, composable with each other and with tensor-
//! parallel sharding ([`crate::shard`]):
//!
//! - [`pipeline`] cuts the [`crate::eval::plan::ModelPlan`] layer walk
//!   into contiguous stages balanced by stored payload bytes and runs
//!   them on persistent workers connected by bounded channels, streaming
//!   micro-batched activations through — outputs stay bit-identical to
//!   the single-engine walk at every stage count, because the stages
//!   execute the *same* layer ops in the same order on the same values,
//!   only on different threads. Each stage may own its own
//!   [`crate::shard::ShardedMatmul`], giving a stages × shards grid.
//! - [`router`] fronts R complete serving engines (lockstep or
//!   continuous) with a placement policy, per-replica admission and
//!   draining, and folds per-replica metrics into one labeled cluster
//!   snapshot.
//!
//! The two compose by construction: a [`PipelinedBackend`] is just an
//! [`crate::coordinator::server::LmBackend`], so a pipelined engine can
//! be one replica behind a [`Router`].

pub mod pipeline;
pub mod router;

pub use pipeline::{
    PipeOpts, PipeStageStat, PipelineExec, PipelinePlan, PipelineWeights, PipelinedBackend,
};
pub use router::{ClusterMetrics, RoutePolicy, Router, RouterOpts};
