//! Pipeline-parallel stage execution over the layer plan.
//!
//! [`PipelinePlan`] cuts a [`ModelPlan`]'s layer walk into `P` contiguous
//! stages, balanced by the **true stored payload bytes** of each layer's
//! quantized linears (the same [`balanced_contiguous`] core the shard
//! planner uses on group cells) — a layer is never split across stages.
//! [`PipelineExec`] runs one persistent worker thread per stage,
//! connected by bounded channels: a forward pass slices its (B × T)
//! residual stream into whole-sequence micro-batches, streams them
//! through the stage chain, and reassembles logits in submission order.
//!
//! **Bit-identity.** Stage `s` runs [`walk_layers`]`(lo_s..hi_s)` and the
//! last stage adds [`finish_walk`] — by the plan module's contract this
//! performs exactly the operations of a single-engine
//! [`walk`](crate::eval::plan::walk), in exactly the same order, for any
//! cut. Micro-batching along the batch dimension is exact too: every
//! per-row op of the dense forward treats sequences independently, so
//! logits are bit-identical to the unpipelined forward at every stage
//! count × micro-batch size (`tests/cluster_parity.rs`).
//!
//! **Composition with tensor parallelism.** Each stage owns its own
//! [`ShardedMatmul`] over the shared container
//! ([`PipelineWeights::Sharded`]), so `--pipeline P --shards N` runs a
//! P×N worker grid; with `shards = 1` the stage degenerates to the
//! single streamed engine, bit-identically (shard parity).

use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::decode_stream::DecodeStats;
use crate::coordinator::server::{gather_last_rows, pad_prefixes, LmBackend};
use crate::eval::native_fwd::{attend_dense, embed_full, DenseLinear, LinearOp};
use crate::eval::plan::{finish_walk, walk_layers, ModelPlan};
use crate::linalg::Mat;
use crate::model::ModelConfig;
use crate::quant::format::QuantizedModel;
use crate::shard::{balanced_contiguous, ShardOpts, ShardStat, ShardedLinear, ShardedMatmul};
use crate::tensor::TensorStore;

/// A contiguous cut of the layer walk into pipeline stages: stage `s`
/// executes layers `stages[s].0 .. stages[s].1`; the last stage also runs
/// the final norm + output head.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PipelinePlan {
    /// half-open layer ranges, contiguous and jointly complete; stages
    /// may be empty when layers are fewer than stages
    pub stages: Vec<(usize, usize)>,
}

impl PipelinePlan {
    /// Cut `plan`'s layers into `stages` runs balanced by each layer's
    /// stored payload bytes in `qm` (the sum over its six quantizable
    /// linears; tensors absent from the container weigh nothing). A
    /// container covering none of the plan's linears falls back to
    /// layer-count balancing, so a dense serve still pipelines sensibly.
    pub fn build(plan: &ModelPlan, qm: &QuantizedModel, stages: usize) -> PipelinePlan {
        let mut weights = Vec::with_capacity(plan.layers.len());
        for l in &plan.layers {
            let names = [&l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w2];
            let bytes: usize =
                names.iter().filter_map(|n| qm.get(n.as_str())).map(|t| t.payload_bytes()).sum();
            weights.push(bytes);
        }
        if weights.iter().all(|&w| w == 0) {
            return PipelinePlan::dense(plan.layers.len(), stages);
        }
        PipelinePlan { stages: balanced_contiguous(&weights, stages.max(1)) }
    }

    /// Layer-count-balanced cut (every layer weighs 1) — the dense-serve
    /// plan, and the fallback when no layer has container payload.
    pub fn dense(n_layer: usize, stages: usize) -> PipelinePlan {
        PipelinePlan { stages: balanced_contiguous(&vec![1; n_layer], stages.max(1)) }
    }

    /// Number of stages (including empty ones).
    pub fn stages(&self) -> usize {
        self.stages.len()
    }
}

/// How pipeline stages apply their quantizable linears.
#[derive(Clone)]
pub enum PipelineWeights {
    /// dense store weights (the seed forward)
    Dense,
    /// each stage owns a [`ShardedMatmul`] over the shared container —
    /// `opts.shards = 1` is the single streamed engine, bit-identically
    Sharded { qm: Arc<QuantizedModel>, opts: ShardOpts },
}

/// Pipeline execution options.
#[derive(Clone, Copy, Debug)]
pub struct PipeOpts {
    /// sequences per micro-batch handed between stages (whole sequences
    /// only — the batch dimension is the exact split axis)
    pub micro_batch: usize,
    /// bounded capacity of each inter-stage channel (how many in-flight
    /// micro-batches a stage may run ahead)
    pub channel_depth: usize,
}

impl Default for PipeOpts {
    fn default() -> Self {
        PipeOpts { micro_batch: 1, channel_depth: 2 }
    }
}

/// Per-stage cumulative counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipeStageStat {
    /// layers this stage executes
    pub layers: usize,
    /// micro-batches processed
    pub micro_batches: usize,
    /// residual-stream rows carried through the stage
    pub rows: usize,
    /// wall time spent executing (not waiting), nanoseconds
    pub busy_ns: u64,
    /// decode traffic of this stage's quantized linears (zero for dense)
    pub decode: DecodeStats,
}

/// One activation hand-off travelling the stage chain. `Fail` carries the
/// first error hit for a micro-batch; downstream stages forward it
/// untouched, so the coordinator always receives one message per chunk.
enum StageMsg {
    Chunk { idx: usize, h: Mat },
    Fail { idx: usize, message: String },
}

/// Where a stage sends its output: the next stage's bounded channel, or
/// the coordinator's unbounded collection channel (unbounded so the last
/// stage never blocks — the pipeline always drains).
enum Next {
    Stage(mpsc::SyncSender<StageMsg>),
    Out(mpsc::Sender<StageMsg>),
}

impl Next {
    /// Deliver downstream; false when the receiver is gone (shutdown).
    fn send(&self, msg: StageMsg) -> bool {
        match self {
            Next::Stage(tx) => tx.send(msg).is_ok(),
            Next::Out(tx) => tx.send(msg).is_ok(),
        }
    }
}

/// Execute one stage's slice of the plan over a micro-batch: layers
/// `lo..hi`, plus the final norm + output head when this is the last
/// stage. Returns the matrix to hand downstream (residual stream or
/// logits).
fn run_stage(
    cfg: &ModelConfig,
    plan: &ModelPlan,
    store: &TensorStore,
    lin: &mut dyn LinearOp,
    mut h: Mat,
    lo: usize,
    hi: usize,
    is_last: bool,
) -> Result<Mat> {
    let batch = h.rows / cfg.seq_len;
    walk_layers(
        plan,
        store,
        lin,
        &mut h,
        None,
        |_, q, k, v| Ok(attend_dense(cfg, batch, q, k, v)),
        lo,
        hi,
    )?;
    if is_last {
        finish_walk(plan, store, lin, &h, None)
    } else {
        Ok(h)
    }
}

/// Everything one stage worker owns, bundled for the thread spawn.
struct StageCtx {
    stage: usize,
    range: (usize, usize),
    is_last: bool,
    cfg: ModelConfig,
    store: Arc<TensorStore>,
    weights: PipelineWeights,
    next: Next,
    stats: Arc<Mutex<Vec<PipeStageStat>>>,
    shard_stats: Arc<Mutex<Vec<Vec<ShardStat>>>>,
}

fn sharded_lin<'a>(exec: &'a ShardedMatmul, store: &'a TensorStore) -> ShardedLinear<'a> {
    ShardedLinear { exec, store, stats: DecodeStats::default() }
}

/// The persistent stage worker: owns this stage's linear operator (and
/// shard executor, when sharded), answers micro-batches until its input
/// channel closes, then closes its own output — shutdown cascades down
/// the chain.
fn stage_worker(ctx: StageCtx, rx: mpsc::Receiver<StageMsg>) {
    let StageCtx { stage, range, is_last, cfg, store, weights, next, stats, shard_stats } = ctx;
    let (lo, hi) = range;
    let plan = ModelPlan::of(&cfg);
    let exec = match &weights {
        PipelineWeights::Dense => None,
        PipelineWeights::Sharded { qm, opts } => Some(ShardedMatmul::new(Arc::clone(qm), *opts)),
    };
    while let Ok(msg) = rx.recv() {
        let out = match msg {
            StageMsg::Fail { idx, message } => StageMsg::Fail { idx, message },
            StageMsg::Chunk { idx, h } => {
                let _sp = crate::span!("pipe_stage");
                let t0 = Instant::now();
                let rows = h.rows;
                let mut decode = DecodeStats::default();
                let res = match &exec {
                    Some(e) => {
                        let mut lin = sharded_lin(e, &store);
                        let r = run_stage(&cfg, &plan, &store, &mut lin, h, lo, hi, is_last);
                        decode = lin.stats;
                        r
                    }
                    None => {
                        let mut lin = DenseLinear { store: &store };
                        run_stage(&cfg, &plan, &store, &mut lin, h, lo, hi, is_last)
                    }
                };
                let busy_ns = t0.elapsed().as_nanos() as u64;
                {
                    let mut all = stats.lock().expect("pipe stats poisoned");
                    let s = &mut all[stage];
                    s.layers = hi - lo;
                    s.micro_batches += 1;
                    s.rows += rows;
                    s.busy_ns += busy_ns;
                    s.decode.merge(&decode);
                }
                if let Some(e) = &exec {
                    let mut per = shard_stats.lock().expect("pipe shard stats poisoned");
                    per[stage] = e.shard_stats();
                }
                match res {
                    Ok(m) => StageMsg::Chunk { idx, h: m },
                    Err(err) => StageMsg::Fail { idx, message: format!("stage {stage}: {err:#}") },
                }
            }
        };
        if !next.send(out) {
            break; // downstream gone: the executor is shutting down
        }
    }
}

/// Pipeline-parallel executor: P persistent stage workers over one model,
/// each carrying its contiguous slice of the layer plan (see module
/// docs). [`PipelineExec::forward`] is `&self`; one executor serves any
/// number of forwards sequentially. Shutdown is automatic on drop.
pub struct PipelineExec {
    cfg: ModelConfig,
    store: Arc<TensorStore>,
    input: Option<mpsc::SyncSender<StageMsg>>,
    out_rx: mpsc::Receiver<StageMsg>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<Vec<PipeStageStat>>>,
    shard_stats: Arc<Mutex<Vec<Vec<ShardStat>>>>,
    micro_batch: usize,
    sharded: bool,
}

impl PipelineExec {
    /// Start the stage workers. Each worker builds its own plan-walk
    /// state — and, when `weights` is sharded, its own [`ShardedMatmul`]
    /// with private decode tables — inside its thread.
    pub fn new(
        cfg: ModelConfig,
        store: TensorStore,
        pplan: PipelinePlan,
        weights: PipelineWeights,
        opts: PipeOpts,
    ) -> PipelineExec {
        let n = pplan.stages.len();
        assert!(n > 0, "pipeline plan has no stages");
        let depth = opts.channel_depth.max(1);
        let store = Arc::new(store);
        let sharded = matches!(weights, PipelineWeights::Sharded { .. });
        let stats = Arc::new(Mutex::new(vec![PipeStageStat::default(); n]));
        let shard_stats = Arc::new(Mutex::new(vec![Vec::new(); n]));
        let (in_tx, first_rx) = mpsc::sync_channel::<StageMsg>(depth);
        let (out_tx, out_rx) = mpsc::channel::<StageMsg>();
        let mut workers = Vec::with_capacity(n);
        let mut stage_rx = Some(first_rx);
        for (s, &range) in pplan.stages.iter().enumerate() {
            let rx = stage_rx.take().expect("stage receiver present");
            let is_last = s + 1 == n;
            let next = if is_last {
                Next::Out(out_tx.clone())
            } else {
                let (tx, nrx) = mpsc::sync_channel::<StageMsg>(depth);
                stage_rx = Some(nrx);
                Next::Stage(tx)
            };
            let ctx = StageCtx {
                stage: s,
                range,
                is_last,
                cfg,
                store: Arc::clone(&store),
                weights: weights.clone(),
                next,
                stats: Arc::clone(&stats),
                shard_stats: Arc::clone(&shard_stats),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("glvq-pipe-{s}"))
                    .spawn(move || stage_worker(ctx, rx))
                    .expect("spawn pipeline stage worker"),
            );
        }
        drop(out_tx);
        PipelineExec {
            cfg,
            store,
            input: Some(in_tx),
            out_rx,
            workers,
            stats,
            shard_stats,
            micro_batch: opts.micro_batch.max(1),
            sharded,
        }
    }

    /// The model configuration the stages execute.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Number of pipeline stages.
    pub fn stages(&self) -> usize {
        self.workers.len()
    }

    /// Per-stage cumulative counters (cheap copy).
    pub fn stage_stats(&self) -> Vec<PipeStageStat> {
        self.stats.lock().expect("pipe stats poisoned").clone()
    }

    /// Per-stage shard counters when stages run tensor-parallel (None
    /// for dense pipelines).
    pub fn shard_stats(&self) -> Option<Vec<Vec<ShardStat>>> {
        if !self.sharded {
            return None;
        }
        Some(self.shard_stats.lock().expect("pipe shard stats poisoned").clone())
    }

    /// Total decode traffic across all stages (None for dense pipelines).
    pub fn decode_stats(&self) -> Option<DecodeStats> {
        if !self.sharded {
            return None;
        }
        let mut total = DecodeStats::default();
        for s in self.stage_stats() {
            total.merge(&s.decode);
        }
        Some(total)
    }

    /// Full (B × T) forward through the stage chain: embed, stream
    /// whole-sequence micro-batches through the pipeline, reassemble
    /// logits (B·T × V) in submission order. Bit-identical to the
    /// single-engine walk at every stage count and micro-batch size.
    pub fn forward(&self, tokens: &[i32], batch: usize) -> Result<Mat> {
        let t = self.cfg.seq_len;
        ensure!(batch > 0, "empty pipeline batch");
        ensure!(tokens.len() == batch * t, "tokens must be batch × seq_len");
        let h = embed_full(&self.cfg, &self.store, tokens, batch)?;
        let d = h.cols;
        let mb = self.micro_batch;
        let n_chunks = batch.div_ceil(mb);
        let input = self.input.as_ref().expect("pipeline input open");
        {
            // sending everything before receiving never deadlocks: the
            // out channel is unbounded, so the chain always drains
            let _sp = crate::span!("pipe_handoff");
            for idx in 0..n_chunks {
                let (b0, b1) = (idx * mb, ((idx + 1) * mb).min(batch));
                let (r0, r1) = (b0 * t, b1 * t);
                let chunk = Mat::from_vec(r1 - r0, d, h.data[r0 * d..r1 * d].to_vec());
                input
                    .send(StageMsg::Chunk { idx, h: chunk })
                    .map_err(|_| anyhow::anyhow!("pipeline stage worker terminated"))?;
            }
        }
        let mut parts: Vec<Option<Mat>> = (0..n_chunks).map(|_| None).collect();
        for _ in 0..n_chunks {
            match self.out_rx.recv().context("pipeline output channel closed early")? {
                StageMsg::Chunk { idx, h } => parts[idx] = Some(h),
                StageMsg::Fail { idx, message } => {
                    anyhow::bail!("pipeline micro-batch {idx} failed: {message}")
                }
            }
        }
        let mut data = Vec::with_capacity(batch * t * self.cfg.vocab);
        let mut rows = 0usize;
        let mut cols = 0usize;
        for p in parts {
            let m = p.expect("one output per micro-batch");
            rows += m.rows;
            cols = m.cols;
            data.extend_from_slice(&m.data);
        }
        Ok(Mat::from_vec(rows, cols, data))
    }
}

impl Drop for PipelineExec {
    fn drop(&mut self) {
        // closing the input cascades: each stage's recv errors, it drops
        // its own sender, and the next stage follows
        self.input.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// [`LmBackend`] over a pipeline executor — the lockstep serving backend
/// for `serve --pipeline P`, slotting into [`ServerHandle`] exactly like
/// the single-engine backends (and bit-identical to them).
///
/// [`ServerHandle`]: crate::coordinator::server::ServerHandle
pub struct PipelinedBackend {
    pub exec: PipelineExec,
}

impl LmBackend for PipelinedBackend {
    fn logits_last(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        Ok(self.logits_last_batch(&[tokens])?.remove(0))
    }

    fn logits_last_batch(&mut self, prefixes: &[&[i32]]) -> Result<Vec<Vec<f32>>> {
        let t = self.exec.config().seq_len;
        let (flat, last) = pad_prefixes(t, prefixes);
        let logits = self.exec.forward(&flat, prefixes.len())?;
        Ok(gather_last_rows(&logits, t, &last))
    }

    fn seq_len(&self) -> usize {
        self.exec.config().seq_len
    }

    fn vocab(&self) -> usize {
        self.exec.config().vocab
    }

    fn decode_stats(&self) -> Option<DecodeStats> {
        self.exec.decode_stats()
    }

    fn shard_stats(&self) -> Option<Vec<ShardStat>> {
        self.exec.shard_stats().map(|per| per.into_iter().flatten().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::native_fwd;
    use crate::model::init_params;
    use crate::quant::format::QuantizedTensor;
    use crate::quant::pack::{code_range, PackedCodes};
    use crate::quant::traits::{QuantizedGroup, SideInfo};
    use crate::util::rng::Rng;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t",
            vocab: 256,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
            seq_len: 16,
            batch_train: 2,
            batch_eval: 2,
        }
    }

    fn toks(cfg: &ModelConfig, batch: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..batch * cfg.seq_len).map(|_| rng.below(256) as i32).collect()
    }

    fn group_of(n_codes: usize) -> QuantizedGroup {
        let (lo, hi) = code_range(2);
        let codes: Vec<i32> = (0..n_codes as i32).map(|i| (i % (hi - lo + 1)) + lo).collect();
        QuantizedGroup {
            method: "rtn",
            bits: 2,
            rows: 8,
            cols: n_codes / 8,
            codes: PackedCodes::pack(&codes, 2).into(),
            side: SideInfo::Uniform { scale: 0.1, zero: 0.0 },
        }
    }

    fn qt(name: &str, n_groups: usize) -> QuantizedTensor {
        let groups = (0..n_groups).map(|gi| (0usize, gi * 8, group_of(64))).collect();
        QuantizedTensor { name: name.into(), rows: 8, cols: n_groups * 8, groups }
    }

    #[test]
    fn dense_plan_balances_layer_counts() {
        assert_eq!(PipelinePlan::dense(4, 2).stages, vec![(0, 2), (2, 4)]);
        let p = PipelinePlan::dense(2, 4);
        assert_eq!(p.stages(), 4);
        assert_eq!(p.stages.first().unwrap().0, 0);
        assert_eq!(p.stages.last().unwrap().1, 2);
        for pair in p.stages.windows(2) {
            assert_eq!(pair[0].1, pair[1].0, "stages not contiguous");
        }
        assert_eq!(p.stages.iter().map(|&(a, b)| b - a).sum::<usize>(), 2);
    }

    #[test]
    fn payload_balanced_plan_isolates_heavy_layers() {
        let plan = ModelPlan::of(&tiny());
        // layer 0 carries 3× the payload of layer 1 → a stage of its own
        let qm = QuantizedModel { tensors: vec![qt("00.attn.wq", 3), qt("01.attn.wq", 1)] };
        let p = PipelinePlan::build(&plan, &qm, 2);
        assert_eq!(p.stages, vec![(0, 1), (1, 2)]);
        // an empty container falls back to layer-count balancing
        let empty = QuantizedModel { tensors: vec![] };
        let fallback = PipelinePlan::build(&plan, &empty, 2);
        assert_eq!(fallback.stages, PipelinePlan::dense(2, 2).stages);
    }

    #[test]
    fn dense_pipeline_is_bit_identical_to_reference_forward() {
        let cfg = tiny();
        let store = init_params(&cfg, 3);
        let x = toks(&cfg, 3, 11);
        let want = native_fwd::forward(&cfg, &store, &x, 3, None).unwrap();
        for stages in [1usize, 2, 4] {
            for micro_batch in [1usize, 2] {
                let exec = PipelineExec::new(
                    cfg,
                    store.clone(),
                    PipelinePlan::dense(cfg.n_layer, stages),
                    PipelineWeights::Dense,
                    PipeOpts { micro_batch, channel_depth: 2 },
                );
                let got = exec.forward(&x, 3).unwrap();
                assert_eq!((got.rows, got.cols), (want.rows, want.cols));
                assert_eq!(got.data, want.data, "stages={stages} mb={micro_batch}");
                let st = exec.stage_stats();
                assert_eq!(st.len(), stages);
                // every stage saw every micro-batch: ceil(3 / mb) chunks
                assert!(st.iter().all(|s| s.micro_batches == 3usize.div_ceil(micro_batch)));
                assert!(exec.shard_stats().is_none() && exec.decode_stats().is_none());
            }
        }
    }

    #[test]
    fn stage_failure_propagates_to_the_caller() {
        let cfg = tiny();
        let mut store = init_params(&cfg, 4);
        store.entries.remove("final.gain"); // break only the last stage
        let exec = PipelineExec::new(
            cfg,
            store,
            PipelinePlan::dense(cfg.n_layer, 2),
            PipelineWeights::Dense,
            PipeOpts::default(),
        );
        let x = toks(&cfg, 2, 5);
        let err = exec.forward(&x, 2).unwrap_err().to_string();
        assert!(err.contains("failed"), "{err}");
        assert!(err.contains("final.gain"), "{err}");
    }
}
