//! Replicated-engine front end: a [`Router`] that owns R independent
//! serving replicas (each a complete lockstep or continuous loop behind a
//! [`ServerHandle`]) and places every request on one of them.
//!
//! The router adds *scale-out*, never *semantics*: replicas are full
//! engines serving the same container, so any placement yields the same
//! response the request would get from a single engine — policy only
//! shifts latency and throughput. That makes the front end safe to grow
//! and shrink: [`Router::drain`] fences a replica off from new placements
//! while its in-flight requests finish (each replica's relay thread keeps
//! forwarding replies after the intake closes, and [`Router::shutdown`]
//! joins the relays before stopping the engines), so an admitted request
//! is never dropped.
//!
//! Admission is two-level. The router's own per-replica outstanding cap
//! ([`RouterOpts::max_outstanding`]) refuses before placement, rendering
//! the same structured [`Backpressure`] reason the engines use (prefixed
//! `router:` so callers can tell the levels apart); each replica's own
//! queue/budget admission still applies after placement. At shutdown the
//! per-replica [`ServerMetrics`] fold into one [`ClusterMetrics`] whose
//! snapshot exports `{replica="N"}`-labeled series next to the cluster
//! aggregates.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::server::{Request, Response, ServerHandle};
use crate::obs::{Mark, MetricsSnapshot, Registry, RequestTimeline};
use crate::serving::queue::token_need;
use crate::serving::Backpressure;

/// Placement policy for new requests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// place on the eligible replica with the fewest outstanding tokens,
    /// ties toward the lowest index — the default; long requests stop
    /// stacking up behind each other
    #[default]
    LeastOutstanding,
    /// strict rotation over the eligible replicas — a deterministic
    /// spread, for tests and uniform-cost workloads
    RoundRobin,
}

/// Router construction options.
#[derive(Clone, Copy, Debug, Default)]
pub struct RouterOpts {
    pub policy: RoutePolicy,
    /// per-replica cap on requests in flight; 0 = unlimited. When every
    /// replica is at its cap (or draining), requests are refused up front
    /// with a `router:`-prefixed [`Backpressure::QueueFull`] reason.
    pub max_outstanding: usize,
}

/// Shared per-replica routing state: bumped by the router at placement,
/// released by the replica's relay thread as replies come back.
#[derive(Default)]
struct ReplicaState {
    /// requests placed but not yet answered
    outstanding_reqs: AtomicUsize,
    /// [`token_need`] of everything outstanding — the load signal behind
    /// [`RoutePolicy::LeastOutstanding`]
    outstanding_tokens: AtomicUsize,
    /// fenced off from new placements ([`Router::drain`])
    draining: AtomicBool,
    /// lifetime requests placed on this replica
    routed: AtomicUsize,
}

/// One placed request a relay thread is waiting on.
struct Pending {
    rx: mpsc::Receiver<Response>,
    reply: mpsc::Sender<Response>,
    /// (replica-side timeline receiver, caller-side sender) when the
    /// request came through [`Router::submit_timed`]
    timeline: Option<(mpsc::Receiver<RequestTimeline>, mpsc::Sender<RequestTimeline>)>,
    tokens: usize,
}

/// Forward one finished reply and release its routing accounting. The
/// counters drop *before* the reply is sent, so a caller holding the
/// response never observes stale outstanding counts.
fn finish(p: Pending, response: Response, state: &ReplicaState) {
    state.outstanding_reqs.fetch_sub(1, Ordering::Relaxed);
    state.outstanding_tokens.fetch_sub(p.tokens, Ordering::Relaxed);
    if let Some((trx, ttx)) = p.timeline {
        // the engine sends the timeline just before the response, so it
        // is already queued whenever the response has arrived
        if let Ok(t) = trx.try_recv() {
            let _ = ttx.send(t);
        }
    }
    let _ = p.reply.send(response);
}

/// Per-replica relay: forwards replica replies back to their callers.
/// Keeps draining in-flight requests after the router closes the intake,
/// so every admitted request is answered before [`Router::shutdown`]
/// joins the thread — the drain-never-drops guarantee.
fn relay_loop(intake: mpsc::Receiver<Pending>, state: Arc<ReplicaState>) {
    let mut pending: Vec<Pending> = Vec::new();
    let mut open = true;
    loop {
        if pending.is_empty() {
            if !open {
                break;
            }
            // idle: block until a request is placed or the router closes
            match intake.recv() {
                Ok(p) => pending.push(p),
                Err(_) => break,
            }
        }
        loop {
            match intake.try_recv() {
                Ok(p) => pending.push(p),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            match pending[i].rx.try_recv() {
                Ok(response) => {
                    finish(pending.swap_remove(i), response, &state);
                    progressed = true;
                }
                Err(mpsc::TryRecvError::Empty) => i += 1,
                Err(mpsc::TryRecvError::Disconnected) => {
                    let message = "replica terminated before answering".to_string();
                    finish(pending.swap_remove(i), Response::Error { message }, &state);
                    progressed = true;
                }
            }
        }
        if !progressed && !pending.is_empty() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

/// Front-end router over R replica engines. Mirrors the [`ServerHandle`]
/// client surface (`submit`/`submit_timed`/`call` plus multi-turn
/// sessions), so callers swap a single engine for a cluster without
/// changing shape.
pub struct Router {
    replicas: Vec<ServerHandle>,
    /// per-replica intake to its relay thread; `None` once shutdown
    /// closed it
    intakes: Vec<Option<mpsc::Sender<Pending>>>,
    relays: Vec<JoinHandle<()>>,
    states: Vec<Arc<ReplicaState>>,
    policy: RoutePolicy,
    max_outstanding: usize,
    rr_next: AtomicUsize,
    rejections: AtomicUsize,
    sessions: Mutex<BTreeMap<u64, Vec<u8>>>,
    next_session: AtomicU64,
}

impl Router {
    /// Take ownership of `replicas` (already-started serving loops — mix
    /// of lockstep and continuous is allowed, though replicas should be
    /// interchangeable engines for routing to be transparent).
    pub fn new(replicas: Vec<ServerHandle>, opts: RouterOpts) -> Router {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        let n = replicas.len();
        let states: Vec<Arc<ReplicaState>> =
            (0..n).map(|_| Arc::new(ReplicaState::default())).collect();
        let mut intakes = Vec::with_capacity(n);
        let mut relays = Vec::with_capacity(n);
        for (i, state) in states.iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Pending>();
            let state = Arc::clone(state);
            let relay = std::thread::Builder::new()
                .name(format!("glvq-relay-{i}"))
                .spawn(move || relay_loop(rx, state))
                .expect("spawn relay thread");
            intakes.push(Some(tx));
            relays.push(relay);
        }
        Router {
            replicas,
            intakes,
            relays,
            states,
            policy: opts.policy,
            max_outstanding: opts.max_outstanding,
            rr_next: AtomicUsize::new(0),
            rejections: AtomicUsize::new(0),
            sessions: Mutex::new(BTreeMap::new()),
            next_session: AtomicU64::new(1),
        }
    }

    /// Number of replicas behind the front end.
    pub fn replicas(&self) -> usize {
        self.states.len()
    }

    /// Requests placed on `replica` and not yet answered.
    pub fn outstanding(&self, replica: usize) -> usize {
        self.states[replica].outstanding_reqs.load(Ordering::Relaxed)
    }

    /// Sum of outstanding requests across the cluster.
    fn total_outstanding(&self) -> usize {
        self.states.iter().map(|s| s.outstanding_reqs.load(Ordering::Relaxed)).sum()
    }

    /// Fence `replica` off from new placements. In-flight requests keep
    /// running to completion; new traffic routes to the other replicas
    /// (or is refused when none remain).
    pub fn drain(&self, replica: usize) {
        self.states[replica].draining.store(true, Ordering::Relaxed);
    }

    /// Re-admit a drained replica to placement.
    pub fn undrain(&self, replica: usize) {
        self.states[replica].draining.store(false, Ordering::Relaxed);
    }

    /// Block until `replica` has no requests in flight (poll + sleep —
    /// pair with [`Router::drain`] to take a replica out safely).
    pub fn wait_drained(&self, replica: usize) {
        while self.outstanding(replica) > 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Pick a replica for a new request, or `None` when every replica is
    /// draining or at its outstanding cap.
    fn place(&self) -> Option<usize> {
        let mut eligible: Vec<usize> = Vec::new();
        for (i, s) in self.states.iter().enumerate() {
            let capped = self.max_outstanding != 0
                && s.outstanding_reqs.load(Ordering::Relaxed) >= self.max_outstanding;
            if !s.draining.load(Ordering::Relaxed) && !capped {
                eligible.push(i);
            }
        }
        if eligible.is_empty() {
            return None;
        }
        let pick = match self.policy {
            RoutePolicy::RoundRobin => {
                let n = self.rr_next.fetch_add(1, Ordering::Relaxed);
                eligible[n % eligible.len()]
            }
            RoutePolicy::LeastOutstanding => {
                let load = |i: usize| self.states[i].outstanding_tokens.load(Ordering::Relaxed);
                *eligible.iter().min_by_key(|&&i| (load(i), i)).expect("eligible is non-empty")
            }
        };
        Some(pick)
    }

    /// Route one request: place it, bump the accounting, hand the replica
    /// reply channel to the relay. No eligible replica → refuse up front.
    fn dispatch(
        &self,
        request: Request,
        reply: mpsc::Sender<Response>,
        timeline: Option<mpsc::Sender<RequestTimeline>>,
    ) {
        let _sp = crate::span!("route");
        let need = token_need(&request);
        let Some(i) = self.place() else {
            self.rejections.fetch_add(1, Ordering::Relaxed);
            let depth = self.total_outstanding();
            let limit = self.max_outstanding * self.states.len();
            let reason = Backpressure::QueueFull { depth, limit }.to_string();
            if let Some(ttx) = timeline {
                // refused before placement: minimal submit → finish
                // timeline, mirroring engine-side admission refusals
                let mut t = RequestTimeline::new(0);
                t.mark(Mark::Finish);
                let _ = ttx.send(t);
            }
            let _ = reply.send(Response::Rejected { reason: format!("router: {reason}") });
            return;
        };
        let state = &self.states[i];
        state.outstanding_reqs.fetch_add(1, Ordering::Relaxed);
        state.outstanding_tokens.fetch_add(need, Ordering::Relaxed);
        state.routed.fetch_add(1, Ordering::Relaxed);
        let (rx, tl) = match timeline {
            Some(ttx) => {
                let (rx, trx) = self.replicas[i].submit_timed(request);
                (rx, Some((trx, ttx)))
            }
            None => (self.replicas[i].submit(request), None),
        };
        let p = Pending { rx, reply, timeline: tl, tokens: need };
        if let Some(tx) = &self.intakes[i] {
            let _ = tx.send(p);
        }
    }

    /// Submit a request to the cluster; returns the response receiver.
    pub fn submit(&self, request: Request) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        self.dispatch(request, reply, None);
        rx
    }

    /// Submit and additionally receive the request's recorded
    /// [`RequestTimeline`], relayed from whichever replica served it.
    /// Like [`ServerHandle::submit_timed`], the timeline arrives before
    /// the response; router-refused requests get a minimal timeline.
    pub fn submit_timed(
        &self,
        request: Request,
    ) -> (mpsc::Receiver<Response>, mpsc::Receiver<RequestTimeline>) {
        let (reply, rx) = mpsc::channel();
        let (ttx, trx) = mpsc::channel();
        self.dispatch(request, reply, Some(ttx));
        (rx, trx)
    }

    /// Convenience: submit and wait.
    pub fn call(&self, request: Request) -> Result<Response> {
        self.submit(request).recv().context("cluster dropped the reply")
    }

    /// Open a multi-turn session seeded with `system`. Sessions live in
    /// the router, not in any one replica: every turn replays the whole
    /// transcript as its prompt, so turns may land on different replicas
    /// (which serve the same container) without changing the answers.
    pub fn begin_session(&self, system: &[u8]) -> u64 {
        let sid = self.next_session.fetch_add(1, Ordering::Relaxed);
        self.sessions.lock().expect("session store poisoned").insert(sid, system.to_vec());
        sid
    }

    /// Run one session turn through the cluster: append `user`, generate
    /// conditioned on the transcript, fold the reply back in.
    pub fn continue_session(&self, sid: u64, user: &[u8], max_new: usize) -> Result<Response> {
        let prompt = {
            let mut sessions = self.sessions.lock().expect("session store poisoned");
            let t = sessions.get_mut(&sid).context("unknown session id")?;
            t.extend_from_slice(user);
            t.clone()
        };
        let resp = self.call(Request::Generate { prompt, max_new })?;
        if let Response::Generated { text } = &resp {
            let mut sessions = self.sessions.lock().expect("session store poisoned");
            if let Some(t) = sessions.get_mut(&sid) {
                t.extend_from_slice(text);
            }
        }
        Ok(resp)
    }

    /// Close a session, returning its final transcript (None for an
    /// unknown id).
    pub fn end_session(&self, sid: u64) -> Option<Vec<u8>> {
        self.sessions.lock().expect("session store poisoned").remove(&sid)
    }

    /// Stop the cluster: close the intakes, join the relays (which drain
    /// every in-flight reply first), then shut each replica down and fold
    /// the per-replica metrics into one [`ClusterMetrics`].
    pub fn shutdown(mut self) -> ClusterMetrics {
        for tx in &mut self.intakes {
            tx.take();
        }
        for relay in self.relays.drain(..) {
            relay.join().expect("relay thread panicked");
        }
        let routed: Vec<usize> =
            self.states.iter().map(|s| s.routed.load(Ordering::Relaxed)).collect();
        let replicas: Vec<ServerMetrics> = self.replicas.drain(..).map(|h| h.shutdown()).collect();
        ClusterMetrics {
            replicas,
            routed,
            router_rejections: self.rejections.load(Ordering::Relaxed),
        }
    }
}

/// Cluster-level metrics: the per-replica [`ServerMetrics`] plus the
/// router's own placement/refusal counters.
#[derive(Debug)]
pub struct ClusterMetrics {
    /// final metrics of each replica engine, in replica order
    pub replicas: Vec<ServerMetrics>,
    /// requests the router placed on each replica
    pub routed: Vec<usize>,
    /// requests refused by the router itself (before placement)
    pub router_rejections: usize,
}

impl ClusterMetrics {
    /// Requests completed across all replicas.
    pub fn requests(&self) -> usize {
        self.replicas.iter().map(|m| m.requests).sum()
    }

    /// Tokens emitted/scored across all replicas.
    pub fn tokens_out(&self) -> usize {
        self.replicas.iter().map(|m| m.tokens_out).sum()
    }

    /// Aggregate throughput: the sum of per-replica rates (replicas run
    /// concurrently over the same wall clock).
    pub fn tokens_per_sec(&self) -> f64 {
        self.replicas.iter().map(|m| m.tokens_per_sec()).sum()
    }

    /// Freeze the cluster view into one [`MetricsSnapshot`]: cluster
    /// aggregates plus a `{replica="N"}`-labeled series family per
    /// replica, so one Prometheus scrape shows both the fleet and the
    /// imbalance between its members.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut reg = Registry::new();
        reg.gauge("cluster_replicas", self.replicas.len() as f64);
        reg.counter("cluster_requests_total", self.requests() as u64);
        reg.counter("cluster_tokens_out_total", self.tokens_out() as u64);
        reg.gauge("cluster_tokens_per_sec", self.tokens_per_sec());
        reg.counter("router_rejections_total", self.router_rejections as u64);
        for (i, m) in self.replicas.iter().enumerate() {
            let id = i.to_string();
            let labels = [("replica", id.as_str())];
            reg.counter_with("replica_routed_total", &labels, self.routed[i] as u64);
            reg.counter_with("replica_requests_total", &labels, m.requests as u64);
            reg.counter_with("replica_tokens_out_total", &labels, m.tokens_out as u64);
            reg.counter_with("replica_rejections_total", &labels, m.rejections.total() as u64);
            reg.gauge_with("replica_tokens_per_sec", &labels, m.tokens_per_sec());
        }
        reg.finish()
    }

    /// Multi-line human summary: one cluster line, then each replica's
    /// own [`ServerMetrics::report`] line indented under it.
    pub fn report(&self) -> String {
        let mut out = format!(
            "cluster: replicas={} requests={} tokens={} tok/s={:.1} router_rejections={}",
            self.replicas.len(),
            self.requests(),
            self.tokens_out(),
            self.tokens_per_sec(),
            self.router_rejections,
        );
        for (i, m) in self.replicas.iter().enumerate() {
            out.push_str(&format!("\n  replica {i} (routed {}): {}", self.routed[i], m.report()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{start, LmBackend, NativeBackend, ServerOpts};
    use crate::model::{init_params, ModelConfig};

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "t",
            vocab: 256,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
            seq_len: 16,
            batch_train: 2,
            batch_eval: 2,
        }
    }

    /// One lockstep replica over the dense native backend. Same seed →
    /// bit-identical engines, so routing is transparent by construction.
    fn replica(cfg: ModelConfig, seed: u64) -> ServerHandle {
        let make = move || -> Result<Box<dyn LmBackend>> {
            let store = init_params(&cfg, seed);
            Ok(Box::new(NativeBackend { cfg, store }))
        };
        start(make, ServerOpts::default())
    }

    fn gen(prompt: &[u8], max_new: usize) -> Request {
        Request::Generate { prompt: prompt.to_vec(), max_new }
    }

    #[test]
    fn round_robin_spreads_requests_and_replicas_agree() {
        let cfg = tiny();
        let handles = vec![replica(cfg, 0), replica(cfg, 0)];
        let opts = RouterOpts { policy: RoutePolicy::RoundRobin, ..RouterOpts::default() };
        let router = Router::new(handles, opts);
        let rxs: Vec<_> = (0..4).map(|_| router.submit(gen(b"ab", 2))).collect();
        let mut texts = Vec::new();
        for rx in rxs {
            match rx.recv().expect("reply") {
                Response::Generated { text } => texts.push(text),
                other => panic!("unexpected response {other:?}"),
            }
        }
        // same-seed replicas are bit-identical: every answer must agree
        for t in &texts[1..] {
            assert_eq!(t, &texts[0], "replicas diverged");
        }
        let metrics = router.shutdown();
        assert_eq!(metrics.routed, vec![2, 2]);
        assert_eq!(metrics.requests(), 4);
        assert_eq!(metrics.tokens_out(), 8);
        assert_eq!(metrics.router_rejections, 0);
    }

    #[test]
    fn least_outstanding_breaks_ties_toward_the_first_replica() {
        let cfg = tiny();
        let handles = vec![replica(cfg, 0), replica(cfg, 0)];
        let router = Router::new(handles, RouterOpts::default());
        // sequential calls always see both replicas idle (the relay
        // releases the accounting before the reply is delivered), so the
        // tie-break sends everything to replica 0
        for _ in 0..3 {
            router.call(gen(b"ab", 1)).expect("reply");
        }
        let metrics = router.shutdown();
        assert_eq!(metrics.routed, vec![3, 0]);
    }

    #[test]
    fn draining_all_replicas_rejects_up_front() {
        let cfg = tiny();
        let router = Router::new(vec![replica(cfg, 0)], RouterOpts::default());
        router.drain(0);
        let (rx, trx) = router.submit_timed(gen(b"ab", 1));
        match rx.recv().expect("reply") {
            Response::Rejected { reason } => {
                assert!(reason.starts_with("router: queue full"), "{reason}")
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        let t = trx.recv().expect("rejected requests still get a minimal timeline");
        assert!(t.first(Mark::Finish).is_some());
        router.undrain(0);
        let resp = router.call(gen(b"ab", 1)).expect("reply");
        assert!(matches!(resp, Response::Generated { .. }));
        router.wait_drained(0);
        let metrics = router.shutdown();
        assert_eq!(metrics.router_rejections, 1);
        assert_eq!(metrics.requests(), 1);
    }

    #[test]
    fn submit_timed_forwards_replica_timelines() {
        let cfg = tiny();
        let router = Router::new(vec![replica(cfg, 0)], RouterOpts::default());
        let (rx, trx) = router.submit_timed(gen(b"ab", 1));
        assert!(matches!(rx.recv().expect("reply"), Response::Generated { .. }));
        let t = trx.recv().expect("timeline forwarded through the relay");
        assert!(t.first(Mark::Finish).is_some());
        router.shutdown();
    }

    #[test]
    fn sessions_fold_turns_through_the_cluster() {
        let cfg = tiny();
        let handles = vec![replica(cfg, 0), replica(cfg, 0)];
        let opts = RouterOpts { policy: RoutePolicy::RoundRobin, ..RouterOpts::default() };
        let router = Router::new(handles, opts);
        let sid = router.begin_session(b"sys ");
        let t1 = match router.continue_session(sid, b"one ", 2).expect("turn 1") {
            Response::Generated { text } => text,
            other => panic!("turn 1: {other:?}"),
        };
        let t2 = match router.continue_session(sid, b"two ", 2).expect("turn 2") {
            Response::Generated { text } => text,
            other => panic!("turn 2: {other:?}"),
        };
        let transcript = router.end_session(sid).expect("open session");
        let mut want = b"sys one ".to_vec();
        want.extend_from_slice(&t1);
        want.extend_from_slice(b"two ");
        want.extend_from_slice(&t2);
        assert_eq!(transcript, want);
        assert!(router.end_session(sid).is_none());
        router.shutdown();
    }

    #[test]
    fn cluster_snapshot_exports_labeled_replica_series() {
        let cfg = tiny();
        let handles = vec![replica(cfg, 0), replica(cfg, 0)];
        let opts = RouterOpts { policy: RoutePolicy::RoundRobin, ..RouterOpts::default() };
        let router = Router::new(handles, opts);
        for _ in 0..2 {
            router.call(gen(b"ab", 1)).expect("reply");
        }
        let metrics = router.shutdown();
        let snap = metrics.snapshot();
        assert_eq!(snap.counter("cluster_requests_total"), 2);
        assert_eq!(snap.counter("cluster_tokens_out_total"), 2);
        assert_eq!(snap.gauge("cluster_replicas") as usize, 2);
        assert_eq!(snap.counter_labeled("replica_routed_total", &[("replica", "0")]), 1);
        assert_eq!(snap.counter_labeled("replica_routed_total", &[("replica", "1")]), 1);
        assert_eq!(snap.counter_family("replica_requests_total"), 2);
        crate::obs::registry::validate_prometheus(&snap.to_prometheus()).unwrap();
        let line = metrics.report();
        assert!(line.starts_with("cluster: replicas=2"), "{line}");
        assert!(line.contains("replica 0 (routed 1)"), "{line}");
        assert!(line.contains("replica 1 (routed 1)"), "{line}");
    }
}
