//! Typed run configuration: quantization settings, training settings, and
//! JSON (de)serialization with validation. Presets cover the paper's main
//! configurations (GLVQ-8D / GLVQ-16D / GLVQ-32D at 2/3/4 bits).

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Lattice-index assignment algorithm (paper default: Babai; GCD is the
/// Tables-12/13 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Assignment {
    Babai,
    Gcd,
}

impl Assignment {
    pub fn name(&self) -> &'static str {
        match self {
            Assignment::Babai => "babai",
            Assignment::Gcd => "gcd",
        }
    }

    pub fn parse(s: &str) -> Result<Assignment> {
        match s {
            "babai" => Ok(Assignment::Babai),
            "gcd" => Ok(Assignment::Gcd),
            _ => bail!("unknown assignment '{s}' (babai|gcd)"),
        }
    }
}

/// Full GLVQ quantization configuration (paper §3 + ablation switches).
#[derive(Clone, Debug, PartialEq)]
pub struct GlvqConfig {
    /// lattice dimension d ∈ {8, 16, 32}
    pub lattice_dim: usize,
    /// target average bits per weight (can be fractional via SDBA mixing)
    pub target_bits: f64,
    /// columns per group (paper default 128; Table 9/10 sweeps this)
    pub group_size: usize,
    /// salience-determined bit allocation on/off (Table 6 ablation)
    pub bit_allocation: bool,
    /// learn per-group lattice (off = shared fixed lattice, Table 7)
    pub adaptive_lattice: bool,
    /// learn per-group μ (off = fixed global μ, Table 8)
    pub adaptive_companding: bool,
    /// index assignment (Babai vs GCD, Tables 12/13)
    pub assignment: Assignment,
    /// alternating-optimization iterations per group
    pub iters: usize,
    /// Adam learning rate for G, *relative* to the basis magnitude
    pub lr_g: f32,
    /// Adam learning rate for μ
    pub lr_mu: f32,
    /// Frobenius regularization λ (paper: 0.1)
    pub lambda: f32,
    /// relative-improvement stop threshold ε
    pub epsilon: f32,
    /// spectral band for G, relative to the initial σ_max:
    /// σ(G) kept within [σ_min·σ_max(G₀), σ_max·σ_max(G₀)]
    pub sigma_min: f32,
    pub sigma_max: f32,
    /// calibration vectors per group
    pub calib_n: usize,
    /// run group optimization through the PJRT glvq_step artifacts instead
    /// of the native optimizer (canonical shapes only)
    pub use_pjrt: bool,
    pub seed: u64,
}

impl Default for GlvqConfig {
    fn default() -> Self {
        GlvqConfig {
            lattice_dim: 16,
            target_bits: 2.0,
            group_size: 128,
            bit_allocation: true,
            adaptive_lattice: true,
            adaptive_companding: true,
            assignment: Assignment::Babai,
            iters: 24,
            lr_g: 0.1,
            lr_mu: 2.0,
            lambda: 0.1,
            epsilon: 1e-4,
            sigma_min: 0.02,
            sigma_max: 4.0,
            calib_n: 256,
            use_pjrt: false,
            seed: 0,
        }
    }
}

impl GlvqConfig {
    /// Paper variants: "glvq-8d", "glvq-16d", "glvq-32d", and the uniform
    /// (no bit allocation) "-u" versions from Table 4.
    pub fn preset(name: &str) -> Result<GlvqConfig> {
        let mut c = GlvqConfig::default();
        match name {
            "glvq-8d" => c.lattice_dim = 8,
            "glvq-16d" => c.lattice_dim = 16,
            "glvq-32d" => c.lattice_dim = 32,
            "glvq-8d-u" => {
                c.lattice_dim = 8;
                c.bit_allocation = false;
            }
            "glvq-32d-u" => {
                c.lattice_dim = 32;
                c.bit_allocation = false;
            }
            _ => bail!("unknown preset '{name}'"),
        }
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if !self.lattice_dim.is_power_of_two() || !(2..=64).contains(&self.lattice_dim) {
            bail!("lattice_dim must be a power of two in [2, 64]");
        }
        if self.group_size % self.lattice_dim != 0 {
            bail!(
                "group_size {} must be divisible by lattice_dim {}",
                self.group_size,
                self.lattice_dim
            );
        }
        if !(0.5..=8.0).contains(&self.target_bits) {
            bail!("target_bits out of range");
        }
        if self.sigma_min >= self.sigma_max {
            bail!("sigma band empty");
        }
        if self.iters == 0 {
            bail!("iters must be > 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lattice_dim", Json::num(self.lattice_dim as f64)),
            ("target_bits", Json::num(self.target_bits)),
            ("group_size", Json::num(self.group_size as f64)),
            ("bit_allocation", Json::Bool(self.bit_allocation)),
            ("adaptive_lattice", Json::Bool(self.adaptive_lattice)),
            ("adaptive_companding", Json::Bool(self.adaptive_companding)),
            ("assignment", Json::str(self.assignment.name())),
            ("iters", Json::num(self.iters as f64)),
            ("lr_g", Json::num(self.lr_g as f64)),
            ("lr_mu", Json::num(self.lr_mu as f64)),
            ("lambda", Json::num(self.lambda as f64)),
            ("epsilon", Json::num(self.epsilon as f64)),
            ("sigma_min", Json::num(self.sigma_min as f64)),
            ("sigma_max", Json::num(self.sigma_max as f64)),
            ("calib_n", Json::num(self.calib_n as f64)),
            ("use_pjrt", Json::Bool(self.use_pjrt)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GlvqConfig> {
        let d = GlvqConfig::default();
        let get_n = |k: &str, dv: f64| j.get(k).as_f64().unwrap_or(dv);
        let get_b = |k: &str, dv: bool| j.get(k).as_bool().unwrap_or(dv);
        let c = GlvqConfig {
            lattice_dim: get_n("lattice_dim", d.lattice_dim as f64) as usize,
            target_bits: get_n("target_bits", d.target_bits),
            group_size: get_n("group_size", d.group_size as f64) as usize,
            bit_allocation: get_b("bit_allocation", d.bit_allocation),
            adaptive_lattice: get_b("adaptive_lattice", d.adaptive_lattice),
            adaptive_companding: get_b("adaptive_companding", d.adaptive_companding),
            assignment: Assignment::parse(
                j.get("assignment").as_str().unwrap_or("babai"),
            )?,
            iters: get_n("iters", d.iters as f64) as usize,
            lr_g: get_n("lr_g", d.lr_g as f64) as f32,
            lr_mu: get_n("lr_mu", d.lr_mu as f64) as f32,
            lambda: get_n("lambda", d.lambda as f64) as f32,
            epsilon: get_n("epsilon", d.epsilon as f64) as f32,
            sigma_min: get_n("sigma_min", d.sigma_min as f64) as f32,
            sigma_max: get_n("sigma_max", d.sigma_max as f64) as f32,
            calib_n: get_n("calib_n", d.calib_n as f64) as usize,
            use_pjrt: get_b("use_pjrt", d.use_pjrt),
            seed: get_n("seed", d.seed as f64) as u64,
        };
        c.validate()?;
        Ok(c)
    }
}

/// Training run settings for the AOT train-step driver.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    pub steps: usize,
    pub lr: f32,
    pub corpus_bytes: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { model: "s".into(), steps: 300, lr: 3e-3, corpus_bytes: 1 << 21, seed: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        GlvqConfig::default().validate().unwrap();
    }

    #[test]
    fn presets_resolve() {
        assert_eq!(GlvqConfig::preset("glvq-8d").unwrap().lattice_dim, 8);
        assert!(!GlvqConfig::preset("glvq-32d-u").unwrap().bit_allocation);
        assert!(GlvqConfig::preset("nope").is_err());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = GlvqConfig::default();
        c.group_size = 100; // not divisible by 16
        assert!(c.validate().is_err());
        let mut c = GlvqConfig::default();
        c.lattice_dim = 12;
        assert!(c.validate().is_err());
        let mut c = GlvqConfig::default();
        c.sigma_min = 5.0; // above sigma_max=4.0 ⇒ empty band
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = GlvqConfig::preset("glvq-32d").unwrap();
        c.target_bits = 1.5;
        c.assignment = Assignment::Gcd;
        let j = c.to_json();
        let c2 = GlvqConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn from_json_applies_defaults_for_missing_keys() {
        let j = Json::parse(r#"{"lattice_dim": 8}"#).unwrap();
        let c = GlvqConfig::from_json(&j).unwrap();
        assert_eq!(c.lattice_dim, 8);
        assert_eq!(c.group_size, 128);
    }
}
