"""L2: decoder-only transformer in JAX — the model whose weights GLVQ compresses.

Build-time only. The forward/loss/train-step graphs defined here are lowered
once by `aot.py` to HLO text and executed from the rust runtime (L3). Python
is never on the request path.

Conventions (mirrored exactly by rust `eval/native_fwd.rs`):
  - byte-level vocab (V=256), learned absolute positional embedding
  - pre-RMSNorm blocks, multi-head causal attention, tanh-GELU MLP
  - all matmul weights stored (n_in, n_out); activations `x @ W`
  - params are a flat {name: array} dict, canonical order = sorted(names)

Nothing here may lower to a typed-FFI custom call (xla_extension 0.5.1
rejects API_VERSION_TYPED_FFI): no jnp.linalg.*, no jax.random inside graphs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyperparameters (baked into lowered HLO shapes)."""

    name: str
    vocab: int = 256
    d_model: int = 128
    n_layer: int = 4
    n_head: int = 4
    d_ff: int = 512
    seq_len: int = 128
    batch_train: int = 16
    batch_eval: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...], bool]]:
        """(name, shape, quantizable) in canonical (sorted-name) order.

        `quantizable` marks the 2-D matmul weights GLVQ compresses; norms,
        embeddings and positional tables stay in full precision (same policy
        as the paper's Llama setup, which keeps embeddings/norms FP16).
        """
        specs: List[Tuple[str, Tuple[int, ...], bool]] = []
        specs.append(("emb", (self.vocab, self.d_model), False))
        specs.append(("final.gain", (self.d_model,), False))
        specs.append(("out", (self.d_model, self.vocab), True))
        specs.append(("pos", (self.seq_len, self.d_model), False))
        for i in range(self.n_layer):
            p = f"{i:02d}."
            specs.append((p + "attn.gain", (self.d_model,), False))
            specs.append((p + "attn.wk", (self.d_model, self.d_model), True))
            specs.append((p + "attn.wo", (self.d_model, self.d_model), True))
            specs.append((p + "attn.wq", (self.d_model, self.d_model), True))
            specs.append((p + "attn.wv", (self.d_model, self.d_model), True))
            specs.append((p + "mlp.gain", (self.d_model,), False))
            specs.append((p + "mlp.w1", (self.d_model, self.d_ff), True))
            specs.append((p + "mlp.w2", (self.d_ff, self.d_model), True))
        specs.sort(key=lambda s: s[0])
        return specs

    def param_count(self) -> int:
        n = 0
        for _, shape, _ in self.param_specs():
            c = 1
            for s in shape:
                c *= s
            n += c
        return n


# Canonical model family: the substitution for Llama 7B/13B/70B (DESIGN.md §3).
CONFIGS: Dict[str, ModelConfig] = {
    "s": ModelConfig(name="s", d_model=128, n_layer=4, n_head=4, d_ff=512),
    "m": ModelConfig(name="m", d_model=256, n_layer=6, n_head=8, d_ff=1024),
    "l": ModelConfig(name="l", d_model=512, n_layer=8, n_head=8, d_ff=2048),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Scaled-normal init; deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jnp.ndarray] = {}
    specs = cfg.param_specs()
    keys = jax.random.split(key, len(specs))
    for (name, shape, _), k in zip(specs, keys):
        if name.endswith("gain"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "pos":
            params[name] = 0.01 * jax.random.normal(k, shape, jnp.float32)
        else:
            fan_in = shape[0]
            scale = 0.5 / float(jnp.sqrt(jnp.float32(fan_in)))
            # residual-output projections get the depth-scaled init
            if name.endswith(("wo", "w2")):
                scale = scale / float(jnp.sqrt(jnp.float32(2.0 * cfg.n_layer)))
            params[name] = scale * jax.random.normal(k, shape, jnp.float32)
    return params


def params_to_list(params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[k] for k in sorted(params.keys())]


def list_to_params(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    names = [n for n, _, _ in cfg.param_specs()]
    assert len(names) == len(flat)
    return dict(zip(names, flat))


def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    # tanh approximation — matched by rust native_fwd
    return jax.nn.gelu(x, approximate=True)


def attention(h: jnp.ndarray, p: Dict[str, jnp.ndarray], prefix: str, cfg: ModelConfig) -> jnp.ndarray:
    B, T, D = h.shape
    H, dh = cfg.n_head, cfg.d_head
    a = rmsnorm(h, p[prefix + "attn.gain"])
    q = (a @ p[prefix + "attn.wq"]).reshape(B, T, H, dh)
    k = (a @ p[prefix + "attn.wk"]).reshape(B, T, H, dh)
    v = (a @ p[prefix + "attn.wv"]).reshape(B, T, H, dh)
    att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((T, T), jnp.float32))
    att = jnp.where(mask > 0, att, jnp.float32(-1e9))
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, D)
    return h + o @ p[prefix + "attn.wo"]


def mlp(h: jnp.ndarray, p: Dict[str, jnp.ndarray], prefix: str) -> jnp.ndarray:
    m = rmsnorm(h, p[prefix + "mlp.gain"])
    return h + gelu(m @ p[prefix + "mlp.w1"]) @ p[prefix + "mlp.w2"]


def forward(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T) int32 tokens → logits (B, T, V)."""
    B, T = x.shape
    h = p["emb"][x] + p["pos"][None, :T, :]
    for i in range(cfg.n_layer):
        prefix = f"{i:02d}."
        h = attention(h, p, prefix, cfg)
        h = mlp(h, p, prefix)
    h = rmsnorm(h, p["final.gain"])
    return h @ p["out"]


def nll_sum(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Total negative log-likelihood over all (B*T) target positions."""
    logits = forward(cfg, p, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -jnp.sum(tgt)


def mean_loss(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return nll_sum(cfg, p, x, y) / jnp.float32(x.shape[0] * x.shape[1])


# --------------------------------------------------------------------------
# Adam train step (lowered as one HLO program; optimizer state rides along)
# --------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def train_step(
    cfg: ModelConfig,
    params: List[jnp.ndarray],
    m: List[jnp.ndarray],
    v: List[jnp.ndarray],
    t: jnp.ndarray,  # scalar f32 step counter (1-based)
    lr: jnp.ndarray,  # scalar f32
    x: jnp.ndarray,
    y: jnp.ndarray,
):
    """One Adam step. Returns (loss, params', m', v')."""
    pdict = list_to_params(cfg, params)
    loss, grads = jax.value_and_grad(lambda q: mean_loss(cfg, q, x, y))(pdict)
    glist = params_to_list(grads)
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    new_p, new_m, new_v = [], [], []
    for w, mi, vi, g in zip(params, m, v, glist):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * jnp.square(g)
        mhat = mi / bc1
        vhat = vi / bc2
        w = w - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        new_p.append(w)
        new_m.append(mi)
        new_v.append(vi)
    return loss, new_p, new_m, new_v
