"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package is checked by pytest (+hypothesis) against
the function of the same name here. These references are also the semantic
contract for the rust-native implementations (rust/src/{lattice,compand}/).

Shapes follow the GLVQ paper (§3.2): a weight group W_g (m×n) is viewed as
row-major sub-blocks of length d, i.e. a (m, n/d, d) block tensor; lattice
columns live on the last axis, so Babai encoding is `round(blocks @ Ginv^T)`
and decoding is `blocks_z @ G^T`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

MU_MIN = 10.0
MU_MAX = 255.0


def mu_law(x: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Eq. (9): F(x) = sgn(x) ln(1+mu|x|)/ln(1+mu)."""
    return jnp.sign(x) * jnp.log1p(mu * jnp.abs(x)) / jnp.log1p(mu)


def mu_law_inv(y: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Eq. (9): F^-1(y) = sgn(y) ((1+mu)^|y| - 1)/mu."""
    return jnp.sign(y) * (jnp.exp(jnp.abs(y) * jnp.log1p(mu)) - 1.0) / mu


def to_blocks(w: jnp.ndarray, d: int) -> jnp.ndarray:
    """(m, n) -> (m, n/d, d) row-major sub-blocks (paper §3.2 reshape)."""
    m, n = w.shape
    assert n % d == 0, f"group width {n} not divisible by lattice dim {d}"
    return w.reshape(m, n // d, d)


def from_blocks(b: jnp.ndarray) -> jnp.ndarray:
    m, l, d = b.shape
    return b.reshape(m, l * d)


def babai_round(w: jnp.ndarray, ginv: jnp.ndarray) -> jnp.ndarray:
    """Babai rounding (Eq. 6) on the *half-integer* grid:
    z = round(Ginv y - 1/2) per sub-block; decode adds the 1/2 back, so the
    reconstruction levels are symmetric at every bit width (QuIP#'s E8+1/2
    convention; at 1 bit this is sign quantization instead of {-s, 0}).

    w: (m, n) weights already companded; ginv: (d, d). Returns (m, n/d, d)
    integer-valued f32 codes.
    """
    d = ginv.shape[0]
    blocks = to_blocks(w, d)
    return jnp.round(blocks @ ginv.T - 0.5)


def lattice_decode(z: jnp.ndarray, g: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Decode + expand (Eq. 10, shifted grid): w_hat = F^-1(G (z + 1/2)).

    z: (m, l, d) codes; g: (d, d); returns (m, l*d).
    """
    y = (z + 0.5) @ g.T
    return mu_law_inv(from_blocks(y), mu)


def glvq_quantize(w: jnp.ndarray, g: jnp.ndarray, ginv: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Full encode->decode chain (Eq. 10) returning reconstructed weights."""
    z = babai_round(mu_law(w, mu), ginv)
    return lattice_decode(z, g, mu)


def glvq_loss(
    w: jnp.ndarray,
    x: jnp.ndarray,
    g: jnp.ndarray,
    ginv: jnp.ndarray,
    mu: jnp.ndarray,
    g0: jnp.ndarray,
    lam: float = 0.1,
) -> jnp.ndarray:
    """Eq. (11): ||W X - W_hat X||^2 + lam ||G - G0||_F^2.

    Codes are stop-gradiented (the paper's alternating scheme fixes Z during
    the G/mu gradient step); gradients flow through decode only.
    """
    z = jax.lax.stop_gradient(babai_round(mu_law(w, mu), ginv))
    w_hat = lattice_decode(z, g, mu)
    err = (w - w_hat) @ x
    return jnp.sum(jnp.square(err)) + lam * jnp.sum(jnp.square(g - g0))


def glvq_step(w, x, g, ginv, mu, g0, lam: float = 0.1):
    """One alternating-optimization observation: (loss, dG, dmu).

    The Z-step is implicit (Babai refreshed inside); the caller (rust L3
    optimizer) applies the gradient update + spectral clamp + mu projection.
    """
    loss, grads = jax.value_and_grad(glvq_loss, argnums=(2, 4))(w, x, g, ginv, mu, g0, lam)
    return loss, grads[0], grads[1]
