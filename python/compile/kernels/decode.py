"""L1 Pallas kernel: fused lattice decode + mu-law expand (Eq. 10).

w_hat = F_mu^{-1}(G (z + 1/2)) per sub-block (half-integer grid). This is the paper's runtime decode —
a d×d matmul per sub-block (no codebook lookup, unlike AQLM), which on TPU
maps directly onto the MXU:
  (TILE_M * l, d) @ (d, d)    then elementwise expand.

VMEM per grid step (f32): TILE_M*l*d (codes) + d*d + TILE_M*l*d (out)
  = 2 * 128*128*4 + tiny ≈ 131 KiB.

interpret=True (CPU plugin); oracle: kernels/ref.py::lattice_decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128


def _decode_kernel(g_ref, z_ref, mu_ref, o_ref, *, d: int):
    z = z_ref[...]  # (tile, l, d)
    tile, l, _ = z.shape
    y = ((z.reshape(tile * l, d) + 0.5) @ g_ref[...].T).reshape(tile, l * d)
    mu = mu_ref[0, 0]
    o_ref[...] = jnp.sign(y) * (jnp.exp(jnp.abs(y) * jnp.log1p(mu)) - 1.0) / mu


def lattice_decode(z: jnp.ndarray, g: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """z: (m, l, d) codes; g: (d, d); mu scalar → reconstructed (m, l*d)."""
    m, l, d = z.shape
    tile = TILE_M if m % TILE_M == 0 else m
    grid = (m // tile,)
    mu2 = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_decode_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((tile, l, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, l * d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, l * d), jnp.float32),
        interpret=True,
    )(g, z, mu2)
