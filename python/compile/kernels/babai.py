"""L1 Pallas kernel: tiled Babai rounding (Eq. 6) — the GLVQ encode hot-spot.

z = round(Ginv @ y - 1/2) per d-length sub-block (half-integer grid) of each weight row. We tile the
row dimension so each grid step stages one (TILE_M, n) weight panel plus the
(d, d) inverse basis in VMEM and performs a single MXU-shaped matmul
  (TILE_M * n/d, d) @ (d, d)
followed by a vectorized round. The fused variant also applies mu-law
companding (Eq. 9) on the loaded panel before rounding, saving one HBM
round-trip of the companded intermediate.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA encode
kernel stages codebooks in shared memory per threadblock; here BlockSpec
expresses the HBM→VMEM schedule and the systolic MXU plays the role of the
warp GEMV. interpret=True everywhere — the CPU PJRT plugin cannot execute
Mosaic custom-calls; correctness is validated against kernels/ref.py.

VMEM footprint per grid step (f32): TILE_M*n + d*d + TILE_M*n  (in+out)
  = 128*128*4 * 2 + tiny  ≈ 131 KiB   « 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128


def _babai_kernel(ginv_ref, w_ref, z_ref, *, d: int):
    w = w_ref[...]  # (tile, n) already companded
    tile, n = w.shape
    blocks = w.reshape(tile * (n // d), d)
    z = jnp.round(blocks @ ginv_ref[...].T - 0.5)
    z_ref[...] = z.reshape(tile, n // d, d)


def _babai_compand_kernel(ginv_ref, w_ref, mu_ref, z_ref, *, d: int):
    w = w_ref[...]  # (tile, n) raw weights
    mu = mu_ref[0, 0]
    w = jnp.sign(w) * jnp.log1p(mu * jnp.abs(w)) / jnp.log1p(mu)
    tile, n = w.shape
    blocks = w.reshape(tile * (n // d), d)
    z = jnp.round(blocks @ ginv_ref[...].T - 0.5)
    z_ref[...] = z.reshape(tile, n // d, d)


def _tile(m: int) -> int:
    return TILE_M if m % TILE_M == 0 else m


def babai_round(w: jnp.ndarray, ginv: jnp.ndarray) -> jnp.ndarray:
    """w: (m, n) companded; ginv: (d, d) → (m, n/d, d) integer-valued f32."""
    m, n = w.shape
    d = ginv.shape[0]
    assert n % d == 0
    tile = _tile(m)
    grid = (m // tile,)
    return pl.pallas_call(
        functools.partial(_babai_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n // d, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n // d, d), jnp.float32),
        interpret=True,
    )(ginv, w)


def babai_encode(w: jnp.ndarray, ginv: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Fused compand + Babai round. w raw (m, n); mu scalar → (m, n/d, d)."""
    m, n = w.shape
    d = ginv.shape[0]
    assert n % d == 0
    tile = _tile(m)
    grid = (m // tile,)
    mu2 = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_babai_compand_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n // d, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n // d, d), jnp.float32),
        interpret=True,
    )(ginv, w, mu2)
