"""L1 Pallas kernel: elementwise mu-law companding (Eq. 9), tiled over rows.

Kept as a standalone kernel for the non-fused pipeline variant and for
kernel-level testing; the production encode path uses the fused
babai.babai_encode. interpret=True; oracle kernels/ref.py::mu_law.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128


def _mu_law_kernel(x_ref, mu_ref, o_ref):
    x = x_ref[...]
    mu = mu_ref[0, 0]
    o_ref[...] = jnp.sign(x) * jnp.log1p(mu * jnp.abs(x)) / jnp.log1p(mu)


def _mu_law_inv_kernel(y_ref, mu_ref, o_ref):
    y = y_ref[...]
    mu = mu_ref[0, 0]
    o_ref[...] = jnp.sign(y) * (jnp.exp(jnp.abs(y) * jnp.log1p(mu)) - 1.0) / mu


def _call(kernel, x: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    m, n = x.shape
    tile = TILE_M if m % TILE_M == 0 else m
    mu2 = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(m // tile,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, mu2)


def mu_law(x: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """F_mu(x), x: (m, n), mu scalar."""
    return _call(_mu_law_kernel, x, mu)


def mu_law_inv(y: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """F_mu^{-1}(y), y: (m, n), mu scalar."""
    return _call(_mu_law_inv_kernel, y, mu)
