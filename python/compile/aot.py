"""AOT export: lower every L2 graph to HLO *text* + write artifacts/manifest.json.

Run once at build time (`make artifacts`); the rust runtime (L3) is
self-contained afterwards.

Interchange is HLO TEXT, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the `xla` crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts [--models s,m]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import glvq_opt, model

LATTICE_DIMS = [8, 16, 32]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, fname: str, text: str) -> str:
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {fname} ({len(text)} chars)")
    return fname


def export_model(cfg: model.ModelConfig, out_dir: str) -> Dict:
    """Lower train_step / forward_loss / logits for one model size."""
    specs = cfg.param_specs()
    f32, i32 = jnp.float32, jnp.int32
    pspecs = [jax.ShapeDtypeStruct(s, f32) for _, s, _ in specs]
    P = len(pspecs)
    bt, be, T = cfg.batch_train, cfg.batch_eval, cfg.seq_len
    xt = jax.ShapeDtypeStruct((bt, T), i32)
    xe = jax.ShapeDtypeStruct((be, T), i32)
    x1 = jax.ShapeDtypeStruct((1, T), i32)
    scalar = jax.ShapeDtypeStruct((), f32)

    def flat_train(*args):
        params = list(args[:P])
        m = list(args[P : 2 * P])
        v = list(args[2 * P : 3 * P])
        t, lr, x, y = args[3 * P], args[3 * P + 1], args[3 * P + 2], args[3 * P + 3]
        loss, np_, nm, nv = model.train_step(cfg, params, m, v, t, lr, x, y)
        return (loss, *np_, *nm, *nv)

    def flat_loss(*args):
        p = model.list_to_params(cfg, list(args[:P]))
        return (model.nll_sum(cfg, p, args[P], args[P + 1]),)

    def flat_logits(*args):
        p = model.list_to_params(cfg, list(args[:P]))
        return (model.forward(cfg, p, args[P]),)

    name = cfg.name
    print(f"model {name}: {P} params, {cfg.param_count()} weights")
    files = {}
    lowered = jax.jit(flat_train).lower(*pspecs, *pspecs, *pspecs, scalar, scalar, xt, xt)
    files["train_step"] = _write(out_dir, f"train_step_{name}.hlo.txt", to_hlo_text(lowered))
    lowered = jax.jit(flat_loss).lower(*pspecs, xe, xe)
    files["forward_loss"] = _write(out_dir, f"forward_loss_{name}.hlo.txt", to_hlo_text(lowered))
    lowered = jax.jit(flat_logits).lower(*pspecs, x1)
    files["logits"] = _write(out_dir, f"logits_{name}.hlo.txt", to_hlo_text(lowered))

    return {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layer": cfg.n_layer,
            "n_head": cfg.n_head,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch_train": cfg.batch_train,
            "batch_eval": cfg.batch_eval,
        },
        "params": [
            {"name": n, "shape": list(s), "quantizable": q} for n, s, q in specs
        ],
        "programs": files,
    }


def export_glvq(d: int, out_dir: str) -> Dict:
    """Lower glvq_step / encode / decode for one lattice dimension."""
    ts = glvq_opt.tile_specs(d)
    print(f"glvq d={d}")
    files = {}
    lowered = jax.jit(glvq_opt.glvq_step).lower(
        ts["w"], ts["x"], ts["g"], ts["ginv"], ts["mu"], ts["g0"]
    )
    files["step"] = _write(out_dir, f"glvq_step_d{d}.hlo.txt", to_hlo_text(lowered))
    lowered = jax.jit(glvq_opt.glvq_encode).lower(ts["w"], ts["ginv"], ts["mu"])
    files["encode"] = _write(out_dir, f"glvq_encode_d{d}.hlo.txt", to_hlo_text(lowered))
    lowered = jax.jit(glvq_opt.glvq_decode).lower(ts["z"], ts["g"], ts["mu"])
    files["decode"] = _write(out_dir, f"glvq_decode_d{d}.hlo.txt", to_hlo_text(lowered))
    return {
        "d": d,
        "r": glvq_opt.TILE_R,
        "n": glvq_opt.GROUP_N,
        "ncal": glvq_opt.CALIB_N,
        "lam": 0.1,
        "programs": files,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="s,m", help="comma list from {s,m,l}")
    ap.add_argument("--dims", default="8,16,32", help="lattice dims to export")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest: Dict = {"version": 1, "models": {}, "glvq": {}}
    for ms in [s for s in args.models.split(",") if s]:
        manifest["models"][ms] = export_model(model.CONFIGS[ms], args.out)
    for d in [int(s) for s in args.dims.split(",") if s]:
        manifest["glvq"][str(d)] = export_glvq(d, args.out)

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
