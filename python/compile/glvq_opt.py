"""L2: the GLVQ group-optimization graph (paper Alg. 1, one iteration).

Lowered by aot.py to `glvq_step_d{8,16,32}.hlo.txt` with canonical tile
shapes (R rows × n cols, N calibration vectors). The rust L3 optimizer:
  - computes Ginv with its own linalg (LU) — jnp.linalg.inv would lower to a
    typed-FFI custom call that xla_extension 0.5.1 rejects,
  - splits a group's rows into R-row tiles, pads the last tile with zeros,
  - accumulates (loss, dG, dmu) over tiles, applies Adam + spectral clamp to
    G and projects mu onto [10, 255] (Eq. 12 text).

The Z-step (Babai, Eq. 6) runs *inside* this graph through the L1 Pallas
kernel under stop_gradient — exactly the paper's alternating scheme: Z is
refreshed every iteration, gradients flow only through decode (Eq. 10/11).

Also defined: the pure encode and decode programs used by the accelerated
quantization/runtime paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import babai as babai_kernel
from compile.kernels import decode as decode_kernel
from compile.kernels import ref

# Canonical tile shapes baked into the AOT artifacts.
TILE_R = 128  # rows per tile
GROUP_N = 128  # columns per group (paper default group size)
CALIB_N = 256  # calibration vectors per group


def glvq_step(w, x, g, ginv, mu, g0, lam: float = 0.1):
    """One alternating-opt iteration on a (R, n) weight tile.

    w: (R, n) raw weights          x: (n, N) calibration inputs
    g, ginv, g0: (d, d)            mu: scalar f32 in [10, 255]
    Returns (loss, dG, dmu). Z is recomputed (Babai) and stop-gradiented.
    """

    # Z-step: L1 Pallas fused compand+Babai kernel. Computed OUTSIDE the
    # differentiated closure — pallas_call supports no AD, and the paper's
    # alternating scheme freezes Z during the G/mu gradient step anyway.
    z = babai_kernel.babai_encode(w, ginv, mu)

    def loss_fn(g_, mu_):
        # G/mu-step path: differentiable decode (plain jnp so XLA fuses + AD).
        y = (z + 0.5) @ g_.T  # (R, l, d) — half-integer grid decode
        w_hat = ref.mu_law_inv(y.reshape(w.shape), mu_)
        err = (w - w_hat) @ x
        return jnp.sum(jnp.square(err)) + lam * jnp.sum(jnp.square(g_ - g0))

    loss, (dg, dmu) = jax.value_and_grad(loss_fn, argnums=(0, 1))(g, mu)
    return loss, dg, dmu


def glvq_encode(w, ginv, mu):
    """Final encode of a (R, n) tile → (R, n/d, d) integer codes (f32)."""
    return babai_kernel.babai_encode(w, ginv, mu)


def glvq_decode(z, g, mu):
    """Decode (R, l, d) codes → (R, l*d) reconstructed weights."""
    return decode_kernel.lattice_decode(z, g, mu)


def tile_specs(d: int, r: int = TILE_R, n: int = GROUP_N, ncal: int = CALIB_N):
    """ShapeDtypeStructs for lowering glvq_step at lattice dimension d."""
    f32 = jnp.float32
    return dict(
        w=jax.ShapeDtypeStruct((r, n), f32),
        x=jax.ShapeDtypeStruct((n, ncal), f32),
        g=jax.ShapeDtypeStruct((d, d), f32),
        ginv=jax.ShapeDtypeStruct((d, d), f32),
        mu=jax.ShapeDtypeStruct((), f32),
        g0=jax.ShapeDtypeStruct((d, d), f32),
        z=jax.ShapeDtypeStruct((r, n // d, d), f32),
    )
