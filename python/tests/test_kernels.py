"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes, lattice dims and mu; assert_allclose everywhere.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile.kernels import babai, compand, decode, ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand_group(rng, m, n, scale=0.05):
    return rng.standard_normal((m, n)).astype(np.float32) * scale


def rand_basis(rng, d, scale=0.02):
    """Well-conditioned generation matrix: identity-dominant perturbation."""
    g = np.eye(d, dtype=np.float32) * scale + rng.standard_normal((d, d)).astype(np.float32) * scale * 0.1
    return g


@given(
    m=st.sampled_from([1, 3, 16, 128, 256]),
    blocks=st.integers(1, 8),
    d=st.sampled_from([4, 8, 16, 32]),
    mu=st.floats(10.0, 255.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_mu_law_kernel_matches_ref(m, blocks, d, mu, seed):
    rng = np.random.default_rng(seed)
    x = rand_group(rng, m, blocks * d)
    got = compand.mu_law(jnp.asarray(x), jnp.float32(mu))
    want = ref.mu_law(jnp.asarray(x), jnp.float32(mu))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@given(
    m=st.sampled_from([1, 16, 128]),
    mu=st.floats(10.0, 255.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_mu_law_roundtrip_identity(m, mu, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, size=(m, 64)).astype(np.float32)
    y = compand.mu_law(jnp.asarray(x), jnp.float32(mu))
    back = compand.mu_law_inv(y, jnp.float32(mu))
    assert_allclose(np.asarray(back), x, rtol=1e-4, atol=1e-5)
    # companding maps [-1,1] into [-1,1] (monotone, odd)
    assert np.all(np.abs(np.asarray(y)) <= 1.0 + 1e-5)


@given(
    m=st.sampled_from([1, 4, 128, 384]),
    blocks=st.integers(1, 6),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_babai_round_matches_ref(m, blocks, d, seed):
    rng = np.random.default_rng(seed)
    w = rand_group(rng, m, blocks * d)
    g = rand_basis(rng, d)
    ginv = np.linalg.inv(g).astype(np.float32)
    got = babai.babai_round(jnp.asarray(w), jnp.asarray(ginv))
    want = ref.babai_round(jnp.asarray(w), jnp.asarray(ginv))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert np.asarray(got).shape == (m, blocks, d)


@given(
    m=st.sampled_from([1, 16, 128]),
    blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16]),
    mu=st.floats(10.0, 255.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_fused_encode_matches_ref_chain(m, blocks, d, mu, seed):
    rng = np.random.default_rng(seed)
    w = rand_group(rng, m, blocks * d)
    g = rand_basis(rng, d)
    ginv = np.linalg.inv(g).astype(np.float32)
    got = babai.babai_encode(jnp.asarray(w), jnp.asarray(ginv), jnp.float32(mu))
    want = ref.babai_round(ref.mu_law(jnp.asarray(w), jnp.float32(mu)), jnp.asarray(ginv))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(
    m=st.sampled_from([1, 16, 128]),
    blocks=st.integers(1, 4),
    d=st.sampled_from([4, 8, 16, 32]),
    mu=st.floats(10.0, 255.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_decode_kernel_matches_ref(m, blocks, d, mu, seed):
    rng = np.random.default_rng(seed)
    z = rng.integers(-8, 9, size=(m, blocks, d)).astype(np.float32)
    g = rand_basis(rng, d)
    got = decode.lattice_decode(jnp.asarray(z), jnp.asarray(g), jnp.float32(mu))
    want = ref.lattice_decode(jnp.asarray(z), jnp.asarray(g), jnp.float32(mu))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    assert np.asarray(got).shape == (m, blocks * d)


def test_encode_decode_reconstructs_lattice_points_exactly():
    """Points already on the (companded) lattice survive the round trip."""
    rng = np.random.default_rng(0)
    d, m, blocks = 8, 32, 4
    g = rand_basis(rng, d, scale=0.03)
    ginv = np.linalg.inv(g).astype(np.float32)
    mu = jnp.float32(50.0)
    z0 = rng.integers(-4, 5, size=(m, blocks, d)).astype(np.float32)
    w = ref.lattice_decode(jnp.asarray(z0), jnp.asarray(g), mu)  # on-lattice
    z1 = babai.babai_encode(w, jnp.asarray(ginv), mu)
    assert_allclose(np.asarray(z1), z0, atol=1e-4)


def test_quantization_error_bounded_by_babai_bound():
    """Appendix A sanity: ||y - G z|| <= 0.5 * sum bound for near-orthogonal G."""
    rng = np.random.default_rng(1)
    d = 8
    g = rand_basis(rng, d, scale=0.05)
    ginv = np.linalg.inv(g).astype(np.float32)
    y = rng.standard_normal((16, d)).astype(np.float32) * 0.1
    z = np.round(y @ ginv.T)
    err = np.linalg.norm(y - z @ g.T, axis=1)
    # loose bound: ||e|| = ||G delta|| <= sigma_max(G) * 0.5 * sqrt(d)
    sigma_max = np.linalg.svd(g, compute_uv=False)[0]
    assert np.all(err <= sigma_max * 0.5 * np.sqrt(d) + 1e-6)
