"""L2 glvq_step numerics: gradient correctness + optimization progress."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile import glvq_opt
from compile.kernels import ref


def setup(seed=0, r=16, n=32, d=8, ncal=24):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((r, n)).astype(np.float32) * 0.05
    x = rng.standard_normal((n, ncal)).astype(np.float32)
    g = (np.eye(d) * 0.02 + rng.standard_normal((d, d)) * 0.002).astype(np.float32)
    ginv = np.linalg.inv(g).astype(np.float32)
    mu = np.float32(80.0)
    return map(jnp.asarray, (w, x, g, ginv, mu, g))


def test_step_returns_finite_loss_and_grads():
    w, x, g, ginv, mu, g0 = setup()
    loss, dg, dmu = glvq_opt.glvq_step(w, x, g, ginv, mu, g0)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(dg)))
    assert np.isfinite(float(dmu))
    assert np.asarray(dg).shape == (8, 8)


def test_step_matches_ref_oracle():
    w, x, g, ginv, mu, g0 = setup(seed=3)
    loss, dg, dmu = glvq_opt.glvq_step(w, x, g, ginv, mu, g0)
    loss_r, dg_r, dmu_r = ref.glvq_step(w, x, g, ginv, mu, g0)
    assert_allclose(float(loss), float(loss_r), rtol=1e-4)
    assert_allclose(np.asarray(dg), np.asarray(dg_r), rtol=1e-3, atol=1e-3)
    assert_allclose(float(dmu), float(dmu_r), rtol=1e-3, atol=1e-3)


def test_grad_g_matches_finite_difference():
    w, x, g, ginv, mu, g0 = setup(seed=1, r=8, n=16, d=4, ncal=8)
    _, dg, _ = glvq_opt.glvq_step(w, x, g, ginv, mu, g0)

    def loss_at(gm):
        z = ref.babai_round(ref.mu_law(w, mu), ginv)  # Z frozen, as in step
        w_hat = ref.lattice_decode(z, gm, mu)
        err = (w - w_hat) @ x
        return float(jnp.sum(jnp.square(err)) + 0.1 * jnp.sum(jnp.square(gm - g0)))

    eps = 1e-4
    gnp = np.asarray(g)
    for (i, j) in [(0, 0), (1, 2), (3, 3)]:
        gp, gm_ = gnp.copy(), gnp.copy()
        gp[i, j] += eps
        gm_[i, j] -= eps
        fd = (loss_at(jnp.asarray(gp)) - loss_at(jnp.asarray(gm_))) / (2 * eps)
        assert abs(fd - float(np.asarray(dg)[i, j])) < 2e-2 * max(1.0, abs(fd)), (
            f"G[{i},{j}]: fd={fd} ad={float(np.asarray(dg)[i, j])}"
        )


def test_gradient_descent_on_g_reduces_loss():
    w, x, g, ginv, mu, g0 = setup(seed=2)
    g = np.asarray(g).copy()
    losses = []
    lr = 1e-6
    for _ in range(10):
        ginv_ = jnp.asarray(np.linalg.inv(g).astype(np.float32))
        loss, dg, dmu = glvq_opt.glvq_step(w, x, jnp.asarray(g), ginv_, mu, g0)
        losses.append(float(loss))
        g = g - lr * np.asarray(dg)
    assert losses[-1] < losses[0], losses


def test_encode_decode_programs_roundtrip():
    w, x, g, ginv, mu, g0 = setup(seed=4, r=128, n=128, d=8)
    z = glvq_opt.glvq_encode(w, ginv, mu)
    what = glvq_opt.glvq_decode(z, g, mu)
    ref_what = ref.glvq_quantize(w, g, ginv, mu)
    assert_allclose(np.asarray(what), np.asarray(ref_what), rtol=1e-4, atol=1e-5)
