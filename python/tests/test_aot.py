"""AOT export integrity: manifest consistency + HLO text sanity.

Uses a tiny export (model 's' would be slow to lower repeatedly in CI loops,
so these tests lower the small glvq programs and check the manifest produced
by a scoped aot run into a tmp dir).
"""

import json
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp

from compile import aot, glvq_opt, model


def test_to_hlo_text_produces_parseable_header():
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32), jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:64]
    assert "ENTRY" in text


def test_glvq_step_lowering_has_no_typed_ffi_custom_calls():
    """xla_extension 0.5.1 rejects API_VERSION_TYPED_FFI custom calls; the
    graphs must avoid jnp.linalg.* / jax.random."""
    ts = glvq_opt.tile_specs(8)
    lowered = jax.jit(glvq_opt.glvq_step).lower(
        ts["w"], ts["x"], ts["g"], ts["ginv"], ts["mu"], ts["g0"]
    )
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text, "graph lowered to a custom call"


def test_model_loss_lowering_has_no_custom_calls():
    cfg = model.ModelConfig(name="t", d_model=32, n_layer=1, n_head=2, d_ff=64, seq_len=16, batch_eval=2)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in cfg.param_specs()]
    P = len(specs)

    def flat_loss(*args):
        p = model.list_to_params(cfg, list(args[:P]))
        return (model.nll_sum(cfg, p, args[P], args[P + 1]),)

    xs = jax.ShapeDtypeStruct((2, 16), jnp.int32)
    text = aot.to_hlo_text(jax.jit(flat_loss).lower(*specs, xs, xs))
    assert "custom-call" not in text


def test_export_glvq_writes_files_and_manifest_entry(tmp_path):
    entry = aot.export_glvq(8, str(tmp_path))
    assert entry["d"] == 8 and entry["r"] == 128 and entry["n"] == 128
    for key, fname in entry["programs"].items():
        p = os.path.join(str(tmp_path), fname)
        assert os.path.exists(p), (key, fname)
        head = open(p).read(64)
        assert head.startswith("HloModule")


def test_manifest_schema_for_model_entry(tmp_path):
    cfg = model.ModelConfig(name="t", d_model=32, n_layer=1, n_head=2, d_ff=64, seq_len=16, batch_train=2, batch_eval=2)
    entry = aot.export_model(cfg, str(tmp_path))
    names = [p["name"] for p in entry["params"]]
    assert names == sorted(names)
    assert set(entry["programs"]) == {"train_step", "forward_loss", "logits"}
    assert entry["config"]["d_model"] == 32
    # shapes serializable
    json.dumps(entry)
