"""L2 model sanity: shapes, causality, trainability."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model

TINY = model.ModelConfig(name="tiny", d_model=32, n_layer=2, n_head=2, d_ff=64, seq_len=16, batch_train=4, batch_eval=2)


def test_param_specs_sorted_and_quantizable_flags():
    specs = TINY.param_specs()
    names = [n for n, _, _ in specs]
    assert names == sorted(names)
    qnames = {n for n, _, q in specs if q}
    assert "out" in qnames and "emb" not in qnames and "pos" not in qnames
    for i in range(TINY.n_layer):
        assert f"{i:02d}.attn.wq" in qnames
        assert f"{i:02d}.mlp.gain" not in qnames


def test_forward_shapes_and_finite():
    p = model.init_params(TINY, seed=0)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 256, (4, 16)), jnp.int32)
    logits = model.forward(TINY, p, x)
    assert logits.shape == (4, 16, 256)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality_future_tokens_do_not_affect_past_logits():
    p = model.init_params(TINY, seed=1)
    rng = np.random.default_rng(1)
    x1 = rng.integers(0, 256, (1, 16)).astype(np.int32)
    x2 = x1.copy()
    x2[0, 10:] = rng.integers(0, 256, 6)  # perturb the future
    l1 = np.asarray(model.forward(TINY, p, jnp.asarray(x1)))
    l2 = np.asarray(model.forward(TINY, p, jnp.asarray(x2)))
    np.testing.assert_allclose(l1[0, :10], l2[0, :10], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, 10:], l2[0, 10:])


def test_initial_loss_near_uniform():
    p = model.init_params(TINY, seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, (4, 16)), jnp.int32)
    loss = float(model.mean_loss(TINY, p, x, x))
    assert abs(loss - np.log(256)) < 0.5


def test_train_step_reduces_loss():
    p = model.params_to_list(model.init_params(TINY, seed=0))
    m = [jnp.zeros_like(w) for w in p]
    v = [jnp.zeros_like(w) for w in p]
    rng = np.random.default_rng(0)
    # a memorizable batch: fixed tokens
    x = jnp.asarray(rng.integers(0, 64, (4, 16)), jnp.int32)
    y = jnp.roll(x, -1, axis=1)
    step = jax.jit(lambda p_, m_, v_, t: model.train_step(TINY, p_, m_, v_, t, jnp.float32(1e-2), x, y))
    losses = []
    for t in range(1, 31):
        loss, p, m, v = step(p, m, v, jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_configs_exist_and_divisible():
    for cfg in model.CONFIGS.values():
        assert cfg.d_model % cfg.n_head == 0
        assert cfg.d_model % 128 == 0 or cfg.d_model < 128 or cfg.d_model % 64 == 0
        # quantizable matrices must have input dim divisible by lattice dims
        for _, shape, q in cfg.param_specs():
            if q:
                assert shape[0] % 32 == 0, shape
