//! **What it demonstrates:** the core public API at group granularity —
//! quantize one heavy-tailed weight group with GLVQ (learned lattice +
//! learned μ-law companding, paper Alg. 1) and compare its reconstruction
//! error against the RTN floor at 2/3/4 bits. The full-model pipeline is
//! shown in `e2e_compress.rs`.
//!
//! **Expected output:** one line per bit width showing GLVQ error well
//! below RTN (`glvq/rtn` ratio < 1.0, typically 0.3–0.7), followed by the
//! payload/side-info byte split; exits 0. Runs offline — no artifacts or
//! PJRT needed.
//!
//! Run: `cargo run --release --example quickstart`

use glvq::baselines::rtn::RtnQuantizer;
use glvq::config::GlvqConfig;
use glvq::glvq::optimizer::GlvqGroupQuantizer;
use glvq::linalg::Mat;
use glvq::quant::traits::{recon_error, GroupQuantizer};
use glvq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // A heavy-tailed weight group (the regime GLVQ targets) and a
    // calibration slice of input activations.
    let mut rng = Rng::new(7);
    let weights: Vec<f32> = (0..256 * 128).map(|_| rng.student_t(4.0) as f32 * 0.02).collect();
    let w = Mat::from_vec(256, 128, weights); // paper orientation: m rows × 128 group cols
    let x = Mat::random_normal(128, 256, 1.0, &mut rng); // (n × N) calibration

    println!("group: {}x{} weights, kurtosis {:.2}", w.rows, w.cols,
        glvq::linalg::stats::kurtosis(&w.data));

    for bits in [2u8, 3, 4] {
        // GLVQ: learned lattice + learned mu-law companding (paper Alg. 1)
        let mut cfg = GlvqConfig::default();
        cfg.lattice_dim = 16;
        let fit = GlvqGroupQuantizer::new(cfg).fit(&w, &x, bits);
        let e_glvq = recon_error(&w, &fit.quantized.dequantize(), &x);

        // RTN floor at the same rate
        let q_rtn = RtnQuantizer.quantize(&w, &x, bits);
        let e_rtn = recon_error(&w, &q_rtn.dequantize(), &x);

        println!(
            "{bits}-bit: glvq err {e_glvq:10.3} (mu={:5.1}, {} iters)  |  rtn err {e_rtn:10.3}  |  glvq/rtn = {:.2}x",
            fit.mu,
            fit.iters_run,
            e_glvq / e_rtn
        );
        println!(
            "         payload {} B + side info {} B ({:.2}%)",
            fit.quantized.codes.payload_bytes(),
            fit.quantized.side_bytes(),
            100.0 * fit.quantized.side_bytes() as f64
                / fit.quantized.codes.payload_bytes() as f64
        );
    }
    Ok(())
}
