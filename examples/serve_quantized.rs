//! Serving example: load a quantized container from disk, run the streaming
//! decoder sanity check, then serve a batch of mixed generate/score
//! requests and report latency/throughput metrics.
//!
//! Run: `cargo run --release --example serve_quantized [-- --model s]`

use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatvec};
use glvq::coordinator::server::{self, NativeBackend, Request, Response, ServerOpts};
use glvq::exp::Workspace;
use glvq::glvq::pipeline::dequantized_store;
use glvq::info;
use glvq::quant::format::QuantizedModel;
use glvq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    glvq::util::logging::set_level(glvq::util::logging::Level::Info);
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "s".to_string());
    let mut ws = Workspace::new("artifacts", "runs")?;

    // quantize (or reuse) a 2-bit GLVQ container and persist it
    let store = ws.trained_default(&model)?;
    let path = ws.dir.join(format!("{model}_glvq8_2b.glvq"));
    let qm = if path.exists() {
        info!("loading container {}", path.display());
        QuantizedModel::load(&path)?
    } else {
        let (qm, _) = ws.quantize(&model, "glvq-8d", 2.0, None)?;
        qm.save(&path)?;
        info!("wrote container {}", path.display());
        qm
    };

    // streaming-decode sanity: one token's dequant-GEMV through every layer
    let mut sm = StreamingMatvec::new(16);
    let mut stats = DecodeStats::default();
    let mut rng = Rng::new(3);
    for qt in &qm.tensors {
        let x: Vec<f32> = (0..qt.cols).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0f32; qt.rows];
        sm.matvec(qt, &x, &mut y, &mut stats);
    }
    info!(
        "streaming decode: {} tensors, {:.2} MB touched/token, peak panel {} elems",
        qm.tensors.len(),
        stats.total_bytes() as f64 / 1e6,
        qm.tensors.iter().map(|t| sm.peak_panel_elems(t)).max().unwrap_or(0)
    );

    // serve a burst of requests over the dequantized model
    let dq = dequantized_store(&qm, &store);
    let cfg = ws.model_cfg(&model)?;
    let handle = server::start(
        move || Ok(Box::new(NativeBackend { cfg, store: dq }) as Box<_>),
        ServerOpts { max_batch: 8 },
    );
    let mut rxs = Vec::new();
    for i in 0..12 {
        let req = if i % 3 == 2 {
            Request::Score { prompt: b"the kama ".to_vec(), continuation: b"vove".to_vec() }
        } else {
            Request::Generate { prompt: format!("the sentence {i} ").into_bytes(), max_new: 16 }
        };
        rxs.push(handle.submit(req));
    }
    let mut generated = 0;
    let mut scored = 0;
    for rx in rxs {
        match rx.recv()? {
            Response::Generated { .. } => generated += 1,
            Response::Scored { .. } => scored += 1,
            Response::Error { message } => anyhow::bail!("server error: {message}"),
        }
    }
    let metrics = handle.shutdown();
    info!("served {generated} generates + {scored} scores: {}", metrics.report());
    Ok(())
}
