//! **What it demonstrates:** serving directly from a compressed `.glvq`
//! container *through the paged KV cache* — load (or build) a quantized
//! model, drive the cache-aware streaming backend by hand to measure
//! prefill vs decode throughput (decode steps are O(T) one-token
//! incremental forwards instead of O(T²) full recomputes), then serve a
//! burst of mixed generate/score requests through the lockstep server.
//! Every linear layer still streams panel-by-panel from the compressed
//! codes, and retired KV pages are themselves compressed with the grouped
//! lattice quantizer (8-bit pages here).
//!
//! **Expected output** (values vary with hardware/seed): a
//! "prefill ... tok/s" and a much larger "decode ... tok/s" line with the
//! cache counters (pages in use / quantized, resident bytes), then a
//! server metrics line like `served 8 generates + 4 scores: requests=12
//! ... decoded=...MB ... kv_pages=...` and exit code 0.
//!
//! Run: `make artifacts && cargo run --release --example serve_quantized
//! [-- --model s]`  (needs trained checkpoints, i.e. a PJRT-enabled build)

use std::time::Instant;

use glvq::coordinator::decode_stream::StreamingMatmul;
use glvq::coordinator::scheduler;
use glvq::coordinator::server::{
    self, CachedNativeBackend, LmBackend, Request, Response, ServerOpts,
};
use glvq::eval::native_fwd::argmax_logit;
use glvq::exp::Workspace;
use glvq::info;
use glvq::kvcache::KvCacheOpts;
use glvq::quant::format::QuantizedModel;

fn main() -> anyhow::Result<()> {
    glvq::util::logging::set_level(glvq::util::logging::Level::Info);
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "s".to_string());
    let mut ws = Workspace::new("artifacts", "runs")?;

    // quantize (or reuse) a 2-bit GLVQ container and persist it
    let store = ws.trained_default(&model)?;
    let path = ws.dir.join(format!("{model}_glvq8_2b.glvq"));
    let qm = if path.exists() {
        info!("loading container {}", path.display());
        QuantizedModel::load(&path)?
    } else {
        // container-only quantization: no dense dequantized copy is built
        let qm = ws.quantize_container(&model, "glvq-8d", 2.0, None)?;
        qm.save(&path)?;
        info!("wrote container {}", path.display());
        qm
    };
    let cfg = ws.model_cfg(&model)?;
    let threads = scheduler::default_threads();
    let kv = KvCacheOpts { page_rows: 16, quantize: true, kv_bits: 8, ..Default::default() };

    // ---- drive the cache-aware backend by hand: prefill vs decode ----
    let mut backend = CachedNativeBackend::streaming(
        cfg,
        store.clone(),
        qm.clone(),
        StreamingMatmul::new(16, threads),
        kv,
    );
    let batch = 4usize;
    let gen = 32usize;
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|i| format!("the sentence {i} ").into_bytes().iter().map(|&b| b as i32).collect())
        .collect();
    let mut prefixes = prompts.clone();
    let views: Vec<&[i32]> = prefixes.iter().map(|p| p.as_slice()).collect();
    let t0 = Instant::now();
    let first = backend.logits_last_batch(&views)?;
    let prefill_s = t0.elapsed().as_secs_f64();
    let prompt_tokens: usize = prompts.iter().map(|p| p.len()).sum();
    for (p, l) in prefixes.iter_mut().zip(&first) {
        p.push(argmax_logit(l));
    }
    let t1 = Instant::now();
    for _ in 1..gen {
        let views: Vec<&[i32]> = prefixes.iter().map(|p| p.as_slice()).collect();
        let logits = backend.logits_last_batch(&views)?;
        for (p, l) in prefixes.iter_mut().zip(&logits) {
            p.push(argmax_logit(l));
        }
    }
    let decode_s = t1.elapsed().as_secs_f64();
    let stats = backend.cache_stats().expect("cache-aware backend reports kv stats");
    info!(
        "prefill {:.1} tok/s ({} prompt tokens), decode {:.1} tok/s ({} steps x {batch} seqs)",
        prompt_tokens as f64 / prefill_s.max(1e-9),
        prompt_tokens,
        (batch * (gen - 1)) as f64 / decode_s.max(1e-9),
        gen - 1
    );
    info!(
        "kv cache: {} pages in use (peak {}), {} quantized, {:.1} KB resident, {:.2} MB decoded",
        stats.pages_in_use,
        stats.peak_pages,
        stats.pages_quantized,
        stats.bytes_in_use as f64 / 1e3,
        stats.decoded_bytes as f64 / 1e6
    );
    backend.end_batch();

    // ---- same backend kind behind the lockstep server ----
    let handle = server::start(
        move || {
            let engine = StreamingMatmul::new(16, threads);
            Ok(Box::new(CachedNativeBackend::streaming(cfg, store, qm, engine, kv)) as Box<_>)
        },
        ServerOpts { max_batch: 8 },
    );
    let mut rxs = Vec::new();
    for i in 0..12 {
        let req = if i % 3 == 2 {
            Request::Score { prompt: b"the kama ".to_vec(), continuation: b"vove".to_vec() }
        } else {
            Request::Generate { prompt: format!("the sentence {i} ").into_bytes(), max_new: 16 }
        };
        rxs.push(handle.submit(req));
    }
    let mut generated = 0;
    let mut scored = 0;
    for rx in rxs {
        match rx.recv()? {
            Response::Generated { .. } => generated += 1,
            Response::Scored { .. } => scored += 1,
            Response::Error { message } => anyhow::bail!("server error: {message}"),
            Response::Rejected { reason } => anyhow::bail!("server rejected: {reason}"),
        }
    }
    let metrics = handle.shutdown();
    info!("served {generated} generates + {scored} scores: {}", metrics.report());
    Ok(())
}
