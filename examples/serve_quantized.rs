//! **What it demonstrates:** serving directly from a compressed `.glvq`
//! container — load (or build) a quantized model, sanity-check the batched
//! multi-threaded streaming decoder against the decode-stats model, then
//! serve a burst of mixed generate/score requests through
//! `StreamingNativeBackend`, which runs every linear layer panel-by-panel
//! from the compressed codes (no layer is ever fully dequantized).
//!
//! **Expected output** (values vary with hardware/seed): a "streaming
//! decode" line reporting MB touched per token-batch and a peak panel far
//! below the layer size, then a metrics line like
//! `served 8 generates + 4 scores: requests=12 tokens=... tok/s=...
//! decoded=...MB peak_panel=...elems`, and exit code 0.
//!
//! Run: `make artifacts && cargo run --release --example serve_quantized
//! [-- --model s]`  (needs trained checkpoints, i.e. a PJRT-enabled build)

use glvq::coordinator::decode_stream::{DecodeStats, StreamingMatmul};
use glvq::coordinator::scheduler;
use glvq::coordinator::server::{
    self, Request, Response, ServerOpts, StreamingNativeBackend,
};
use glvq::exp::Workspace;
use glvq::info;
use glvq::linalg::Mat;
use glvq::quant::format::QuantizedModel;
use glvq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    glvq::util::logging::set_level(glvq::util::logging::Level::Info);
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "s".to_string());
    let mut ws = Workspace::new("artifacts", "runs")?;

    // quantize (or reuse) a 2-bit GLVQ container and persist it
    let store = ws.trained_default(&model)?;
    let path = ws.dir.join(format!("{model}_glvq8_2b.glvq"));
    let qm = if path.exists() {
        info!("loading container {}", path.display());
        QuantizedModel::load(&path)?
    } else {
        // container-only quantization: no dense dequantized copy is built
        let qm = ws.quantize_container(&model, "glvq-8d", 2.0, None)?;
        qm.save(&path)?;
        info!("wrote container {}", path.display());
        qm
    };

    // streaming-decode sanity: one batch of 4 "tokens" through every
    // layer; each group-panel is decoded exactly once for the whole batch
    let threads = scheduler::default_threads();
    let engine = StreamingMatmul::new(16, threads);
    let mut stats = DecodeStats::default();
    let mut rng = Rng::new(3);
    for qt in &qm.tensors {
        let x = Mat::random_normal(4, qt.cols, 1.0, &mut rng);
        let mut y = Mat::zeros(4, qt.rows);
        engine.matmul(qt, &x, &mut y, &mut stats);
    }
    info!(
        "streaming decode: {} tensors on {} threads, {:.2} MB touched/batch, peak panel {} elems",
        qm.tensors.len(),
        threads,
        stats.total_bytes() as f64 / 1e6,
        qm.tensors.iter().map(|t| engine.peak_panel_elems(t)).max().unwrap_or(0)
    );

    // serve a burst of requests straight from the compressed weights: the
    // server drains them into lockstep batches, so every decode is
    // amortized across all concurrently-active sequences
    let cfg = ws.model_cfg(&model)?;
    let handle = server::start(
        move || {
            Ok(Box::new(StreamingNativeBackend {
                cfg,
                store,
                qm,
                engine: StreamingMatmul::new(16, threads),
                stats: DecodeStats::default(),
            }) as Box<_>)
        },
        ServerOpts { max_batch: 8 },
    );
    let mut rxs = Vec::new();
    for i in 0..12 {
        let req = if i % 3 == 2 {
            Request::Score { prompt: b"the kama ".to_vec(), continuation: b"vove".to_vec() }
        } else {
            Request::Generate { prompt: format!("the sentence {i} ").into_bytes(), max_new: 16 }
        };
        rxs.push(handle.submit(req));
    }
    let mut generated = 0;
    let mut scored = 0;
    for rx in rxs {
        match rx.recv()? {
            Response::Generated { .. } => generated += 1,
            Response::Scored { .. } => scored += 1,
            Response::Error { message } => anyhow::bail!("server error: {message}"),
        }
    }
    let metrics = handle.shutdown();
    info!("served {generated} generates + {scored} scores: {}", metrics.report());
    Ok(())
}
