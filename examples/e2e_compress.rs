//! **What it demonstrates:** the end-to-end driver (DESIGN.md §6) proving
//! all three layers compose:
//!
//!   1. generate a synthetic corpus (rust data substrate),
//!   2. TRAIN a transformer for a few hundred steps through the AOT
//!      train-step HLO executed by the rust PJRT runtime (L2/L1 → L3),
//!      logging the loss curve,
//!   3. capture calibration activations with the native forward,
//!   4. QUANTIZE with GLVQ (SDBA + companding) and with RTN at 2 bits,
//!   5. EVALUATE perplexity fp32 vs RTN vs GLVQ via the ForwardLoss HLO,
//!   6. SERVE three batched generate requests through the L3 server.
//!
//! **Expected output:** staged `=== [k/4] ... ===` progress lines, a 2-bit
//! wiki-perplexity comparison where GLVQ beats RTN (asserted), server
//! metrics, and a final `e2e compress: OK`; exits 0. Requires trained
//! artifacts (`make artifacts`) — offline builds fail at step 1 with the
//! structured PJRT-unavailable error.
//!
//! Run: `make artifacts && cargo run --release --example e2e_compress`
//! (pass `--model m` for the larger model; results land in runs/e2e/)

use glvq::coordinator::server::{self, NativeBackend, Request, Response, ServerOpts};
use glvq::data::corpus::Mix;
use glvq::exp::Workspace;
use glvq::info;

fn main() -> anyhow::Result<()> {
    glvq::util::logging::set_level(glvq::util::logging::Level::Info);
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "s".to_string());

    let mut ws = Workspace::new("artifacts", "runs")?;

    // --- train through the AOT train-step artifact (loss curve logged) ---
    let steps = Workspace::default_steps(&model);
    info!("=== [1/4] training model {model} for {steps} steps via PJRT train_step ===");
    let store = ws.trained(&model, steps, 3e-3)?;
    info!("loss curve written to runs/e2e/model_{model}.loss.tsv");

    // --- baseline perplexity ---
    info!("=== [2/4] fp32 perplexity (ForwardLoss HLO) ===");
    let fp_wiki = ws.ppl(&model, &store, Mix::Wiki)?;
    let fp_web = ws.ppl(&model, &store, Mix::Web)?;
    info!("fp32: wiki ppl {:.3}, web ppl {:.3}", fp_wiki.ppl, fp_web.ppl);

    // --- quantize ---
    info!("=== [3/4] quantizing at 2 bits: GLVQ-16D (SDBA+companding) vs RTN ===");
    let (qm_glvq, dq_glvq) = ws.quantize(&model, "glvq-16d", 2.0, None)?;
    let (_, dq_rtn) = ws.quantize(&model, "rtn", 2.0, None)?;
    let container = ws.dir.join(format!("{model}_glvq16_2b.glvq"));
    qm_glvq.save(&container)?;
    let (payload, side) = qm_glvq.size_bytes();
    info!(
        "container {}: {:.3} avg bits, {} B payload + {} B side ({:.2}%)",
        container.display(),
        qm_glvq.avg_bits(),
        payload,
        side,
        100.0 * side as f64 / payload as f64
    );

    let g_wiki = ws.ppl(&model, &dq_glvq, Mix::Wiki)?;
    let r_wiki = ws.ppl(&model, &dq_rtn, Mix::Wiki)?;
    info!(
        "2-bit wiki ppl: fp32 {:.3} | GLVQ {:.3} | RTN {:.3}",
        fp_wiki.ppl, g_wiki.ppl, r_wiki.ppl
    );
    assert!(
        g_wiki.ppl < r_wiki.ppl,
        "GLVQ must beat RTN at 2 bits ({} vs {})",
        g_wiki.ppl,
        r_wiki.ppl
    );

    // --- serve ---
    info!("=== [4/4] serving 3 batched generate requests over the GLVQ model ===");
    let cfg = ws.model_cfg(&model)?;
    let handle = server::start(
        move || Ok(Box::new(NativeBackend { cfg, store: dq_glvq }) as Box<_>),
        ServerOpts { max_batch: 4 },
    );
    let rxs: Vec<_> = ["the kama ", "Boku ", "the ri"]
        .iter()
        .map(|p| handle.submit(Request::Generate { prompt: p.as_bytes().to_vec(), max_new: 32 }))
        .collect();
    for (p, rx) in ["the kama ", "Boku ", "the ri"].iter().zip(rxs) {
        match rx.recv()? {
            Response::Generated { text } => {
                info!("prompt {p:?} → {:?}", String::from_utf8_lossy(&text))
            }
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
    let metrics = handle.shutdown();
    info!("server metrics: {}", metrics.report());
    info!("e2e compress: OK");
    Ok(())
}
