//! **What it demonstrates:** the rate-distortion sweep — perplexity vs
//! bits for GLVQ and the strongest baselines, the crossover picture behind
//! the paper's Tables 1–3.
//!
//! **Expected output:** a four-column table (`method bits wiki-ppl Δ vs
//! fp32`) over bits ∈ {4, 3, 2, 1.5, 1} where GLVQ's Δ stays smallest at
//! low rates; exits 0. Requires trained artifacts (`make artifacts`) for
//! the perplexity evaluation.
//!
//! Run: `cargo run --release --example sweep_bits`

use glvq::data::corpus::Mix;
use glvq::exp::Workspace;
use glvq::info;

fn main() -> anyhow::Result<()> {
    glvq::util::logging::set_level(glvq::util::logging::Level::Info);
    let mut ws = Workspace::new("artifacts", "runs")?;
    let model = "s";
    let store = ws.trained_default(model)?;
    let fp = ws.ppl(model, &store, Mix::Wiki)?;
    info!("fp32 wiki ppl: {:.3}", fp.ppl);

    println!("{:<12} {:>6} {:>10} {:>12}", "method", "bits", "wiki ppl", "Δ vs fp32");
    for bits in [4.0f64, 3.0, 2.0, 1.5, 1.0] {
        for method in ["rtn", "gptq", "tcq", "glvq-8d"] {
            // rtn/gptq/tcq are integer-rate methods
            if bits.fract() != 0.0 && method != "glvq-8d" {
                continue;
            }
            if bits < 2.0 && (method == "gptq" || method == "tcq" || method == "rtn") {
                continue; // sub-2-bit handled by binarization baselines (Table 3)
            }
            let (_, dq) = ws.quantize(model, method, bits, None)?;
            let r = ws.ppl(model, &dq, Mix::Wiki)?;
            println!(
                "{:<12} {:>6} {:>10.3} {:>+12.3}",
                method,
                bits,
                r.ppl,
                r.ppl - fp.ppl
            );
        }
    }
    Ok(())
}
